//! Free-order ("hardware-tuned") operators — the stand-in for cuDNN/torch
//! kernels in the paper's overhead benchmarks (§4) and the *source* of the
//! cross-hardware nondeterminism Verde exists to eliminate.
//!
//! Two deliberate differences from [`super::repops`]:
//!
//! 1. **Fused multiply-add.** Like cuDNN's FFMA-based kernels, the matmul
//!    contracts `a*b + c` with a single rounding. This is faster on any FMA
//!    machine and produces different bits than separate mul+add.
//! 2. **Profile-scheduled reductions.** Reductions split into
//!    `profile.lanes` independent partial accumulators (the analogue of
//!    assigning the K loop to multiple threads) and combine them in the
//!    profile's [`CombineOrder`]. Different profiles ⇒ different reduction
//!    trees ⇒ different bits, deterministically *per profile* — a GPU is
//!    self-consistent, but a T4 disagrees with an A100.
//!
//! Everything here is still sequential Rust on one core; what varies by
//! profile is only the floating-point combination order, which is the
//! paper-relevant behaviour (DESIGN.md §4, substitution 1–2).


use super::profile::{CombineOrder, HardwareProfile};
use super::repops::{bmm_dims, mm_dims, rows_lastdim};
use super::Tensor;

/// Combine per-lane partials in the profile's order.
#[inline]
fn combine(partials: &mut [f32], order: CombineOrder) -> f32 {
    match order {
        CombineOrder::Sequential => {
            let mut acc = partials[0];
            for &p in &partials[1..] {
                acc += p;
            }
            acc
        }
        CombineOrder::ReverseSequential => {
            let mut acc = *partials.last().unwrap();
            for &p in partials[..partials.len() - 1].iter().rev() {
                acc += p;
            }
            acc
        }
        CombineOrder::PairwiseTree => {
            let mut n = partials.len();
            while n > 1 {
                let half = n / 2;
                for i in 0..half {
                    partials[i] = partials[2 * i] + partials[2 * i + 1];
                }
                if n % 2 == 1 {
                    partials[half] = partials[n - 1];
                    n = half + 1;
                } else {
                    n = half;
                }
            }
            partials[0]
        }
    }
}

/// Free-order sum: lane-strided partials (`lane c` takes elements
/// `c, c+L, c+2L, …`, like a strided thread assignment) combined per profile.
pub fn sum_slice(xs: &[f32], hw: &HardwareProfile) -> f32 {
    let lanes = hw.lanes.min(xs.len().max(1));
    let mut partials = vec![0.0f32; lanes];
    for (i, &x) in xs.iter().enumerate() {
        partials[i % lanes] += x;
    }
    combine(&mut partials, hw.combine)
}

/// The order in which a profile's K chunks retire — the architecture-
/// dependent schedule a tuned library's threadblocks would induce.
fn chunk_order(lanes: usize, combine: CombineOrder) -> Vec<usize> {
    match combine {
        CombineOrder::Sequential => (0..lanes).collect(),
        CombineOrder::ReverseSequential => (0..lanes).rev().collect(),
        // tree-ish interleave: even chunks first, then odd
        CombineOrder::PairwiseTree => {
            (0..lanes).step_by(2).chain((1..lanes).step_by(2)).collect()
        }
    }
}

/// Hardware-tuned matmul: FMA contraction at full speed (single accumulator
/// row, unit stride), with the K range split into `lanes` chunks retired in
/// the profile's `chunk_order`. Per output element the FP addition order
/// is therefore a function of the profile — deterministic per device,
/// different across devices — at zero cost relative to the fastest schedule.
pub fn matmul(a: &Tensor, b: &Tensor, hw: &HardwareProfile) -> Tensor {
    let (m, k, n) = mm_dims(a, b);
    let mut c = vec![0.0f32; m * n];
    matmul_into(a.data(), b.data(), &mut c, m, k, n, hw);
    Tensor::new([m, n], c)
}

pub(crate) fn matmul_into(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    hw: &HardwareProfile,
) {
    let lanes = hw.lanes.min(k.max(1));
    // chunk boundaries: chunk L owns k in [bounds[L], bounds[L+1])
    let bounds: Vec<usize> = (0..=lanes).map(|l| l * k / lanes).collect();
    let order = chunk_order(lanes, hw.combine);
    // register-tiled j panels, K retired chunk-by-chunk in the profile's
    // order and KB-blocked within each chunk (mirrors repops::mm_kernel so
    // the overhead metric measures ORDER, not blocking quality)
    const JB: usize = 32;
    const KB: usize = 256;

    if k <= KB {
        // small-K fast path: the whole reduction fits one block, so the
        // accumulator stays in registers across ALL chunks (the chunk order
        // still dictates the per-element FP addition order).
        let mut pack = vec![0.0f32; k * JB];
        let mut jb = 0;
        while jb < n {
            let w = JB.min(n - jb);
            for kk in 0..k {
                pack[kk * w..kk * w + w].copy_from_slice(&b[kk * n + jb..kk * n + jb + w]);
            }
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                if w == JB {
                    let mut acc = [0.0f32; JB];
                    for &l in &order {
                        let (c0, c1) = (bounds[l], bounds[l + 1]);
                        for (off, &aik) in arow[c0..c1].iter().enumerate() {
                            let brow = &pack[(c0 + off) * JB..(c0 + off) * JB + JB];
                            for j in 0..JB {
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            }
                        }
                    }
                    c[i * n + jb..i * n + jb + JB].copy_from_slice(&acc);
                } else {
                    let mut acc = [0.0f32; JB];
                    for &l in &order {
                        let (c0, c1) = (bounds[l], bounds[l + 1]);
                        for (off, &aik) in arow[c0..c1].iter().enumerate() {
                            let brow = &pack[(c0 + off) * w..(c0 + off) * w + w];
                            for j in 0..w {
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            }
                        }
                    }
                    c[i * n + jb..i * n + jb + w].copy_from_slice(&acc[..w]);
                }
            }
            jb += w;
        }
        return;
    }

    let mut pack = vec![0.0f32; KB * JB];
    let mut jb = 0;
    while jb < n {
        let w = JB.min(n - jb);
        for &l in &order {
            let (c0, c1) = (bounds[l], bounds[l + 1]);
            let mut kb = c0;
            while kb < c1 {
                let kw = KB.min(c1 - kb);
                for kk in 0..kw {
                    pack[kk * w..kk * w + w]
                        .copy_from_slice(&b[(kb + kk) * n + jb..(kb + kk) * n + jb + w]);
                }
                for i in 0..m {
                    let arow = &a[i * k + kb..i * k + kb + kw];
                    let crow = &mut c[i * n + jb..i * n + jb + w];
                    if w == JB {
                        let mut acc = [0.0f32; JB];
                        acc.copy_from_slice(crow);
                        for (kk, &aik) in arow.iter().enumerate() {
                            let brow = &pack[kk * JB..kk * JB + JB];
                            for j in 0..JB {
                                // single-rounded contraction, like FFMA
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            }
                        }
                        crow.copy_from_slice(&acc);
                    } else {
                        let mut accbuf = [0.0f32; JB];
                        let acc = &mut accbuf[..w];
                        acc.copy_from_slice(crow);
                        for (kk, &aik) in arow.iter().enumerate() {
                            let brow = &pack[kk * w..kk * w + w];
                            for j in 0..w {
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            }
                        }
                        crow.copy_from_slice(acc);
                    }
                }
                kb += kw;
            }
        }
        jb += w;
    }
}

/// Free-order batched matmul.
pub fn bmm(a: &Tensor, b: &Tensor, hw: &HardwareProfile) -> Tensor {
    let (bs, m, k, n) = bmm_dims(a, b);
    let mut c = vec![0.0f32; bs * m * n];
    for ib in 0..bs {
        matmul_into(
            &a.data()[ib * m * k..(ib + 1) * m * k],
            &b.data()[ib * k * n..(ib + 1) * k * n],
            &mut c[ib * m * n..(ib + 1) * m * n],
            m,
            k,
            n,
            hw,
        );
    }
    Tensor::new([bs, m, n], c)
}

/// Free-order softmax: vendor-libm `exp`, profile-scheduled row sums.
pub fn softmax_lastdim(a: &Tensor, hw: &HardwareProfile) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut out = vec![0.0f32; rows * n];
    for r in 0..rows {
        let row = &a.data()[r * n..(r + 1) * n];
        let orow = &mut out[r * n..(r + 1) * n];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        for (o, &x) in orow.iter_mut().zip(row) {
            *o = (x - m).exp();
        }
        let s = sum_slice(orow, hw);
        let inv = 1.0 / s;
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Free-order log-softmax.
pub fn log_softmax_lastdim(a: &Tensor, hw: &HardwareProfile) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut out = vec![0.0f32; rows * n];
    let mut scratch = vec![0.0f32; n];
    for r in 0..rows {
        let row = &a.data()[r * n..(r + 1) * n];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        for (s, &x) in scratch.iter_mut().zip(row) {
            *s = (x - m).exp();
        }
        let lse = sum_slice(&scratch, hw).ln();
        let orow = &mut out[r * n..(r + 1) * n];
        for (o, &x) in orow.iter_mut().zip(row) {
            *o = (x - m) - lse;
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Free-order LayerNorm (profile-scheduled mean/variance sums, libm rsqrt
/// path via `1/sqrt`).
pub fn layernorm(a: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32, hw: &HardwareProfile) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    assert_eq!(gamma.shape(), [n]);
    assert_eq!(beta.shape(), [n]);
    let mut out = vec![0.0f32; rows * n];
    let mut sq = vec![0.0f32; n];
    let inv_n = 1.0 / n as f32;
    for r in 0..rows {
        let row = &a.data()[r * n..(r + 1) * n];
        let mean = sum_slice(row, hw) * inv_n;
        for (s, &x) in sq.iter_mut().zip(row) {
            let d = x - mean;
            *s = d * d;
        }
        let var = sum_slice(&sq, hw) * inv_n;
        let inv_std = 1.0 / (var + eps).sqrt();
        let orow = &mut out[r * n..(r + 1) * n];
        for j in 0..n {
            orow[j] = (row[j] - mean) * inv_std * gamma.data()[j] + beta.data()[j];
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Free-order RMSNorm.
pub fn rmsnorm(a: &Tensor, gamma: &Tensor, eps: f32, hw: &HardwareProfile) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    assert_eq!(gamma.shape(), [n]);
    let mut out = vec![0.0f32; rows * n];
    let mut sq = vec![0.0f32; n];
    let inv_n = 1.0 / n as f32;
    for r in 0..rows {
        let row = &a.data()[r * n..(r + 1) * n];
        for (s, &x) in sq.iter_mut().zip(row) {
            *s = x * x;
        }
        let ms = sum_slice(&sq, hw) * inv_n;
        let inv_rms = 1.0 / (ms + eps).sqrt();
        let orow = &mut out[r * n..(r + 1) * n];
        for j in 0..n {
            orow[j] = row[j] * inv_rms * gamma.data()[j];
        }
    }
    Tensor::new(a.shape().to_vec(), out)
}

/// Free-order elementwise transcendentals use the platform libm — the bits
/// a vendor math library would produce (self-consistent, not portable).
pub fn gelu(a: &Tensor) -> Tensor {
    super::repops::map(a, |x| {
        0.5 * x * (1.0 + libm_erf(x * std::f32::consts::FRAC_1_SQRT_2))
    })
}

pub fn silu(a: &Tensor) -> Tensor {
    super::repops::map(a, |x| x / (1.0 + (-x).exp()))
}

/// `erf` is not in Rust's std; the "vendor" erf is our polynomial with libm
/// exp substituted — close to what a tuned device library ships.
fn libm_erf(x: f32) -> f32 {
    let sign = if x < 0.0 { -1.0f32 } else { 1.0 };
    let ax = sign * x;
    if ax > 4.0 {
        return sign;
    }
    let t = 1.0 / (1.0 + 0.327_591_1 * ax);
    let poly = ((((1.061_405_429 * t - 1.453_152_027) * t + 1.421_413_741) * t
        - 0.284_496_736)
        * t
        + 0.254_829_592)
        * t;
    sign * (1.0 - poly * (-(ax * ax)).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::repops;

    /// Inputs that expose reduction-order sensitivity: wide dynamic range so
    /// different summation orders round differently.
    fn adversarial(shape: &[usize], seed: u64) -> Tensor {
        let mut t = Tensor::rand(shape.to_vec(), seed, 1.0);
        for (i, v) in t.data_mut().iter_mut().enumerate() {
            let mag = ((i * 2654435761) % 24) as i32 - 12;
            *v *= (2.0f32).powi(mag);
        }
        t
    }

    #[test]
    fn baseline_close_to_repops() {
        // numerically the same answer (to rounding), bitwise not required
        let a = Tensor::rand([16, 32], 1, 1.0);
        let b = Tensor::rand([32, 8], 2, 1.0);
        let free = matmul(&a, &b, &HardwareProfile::T4_16G);
        let rep = repops::matmul(&a, &b);
        assert!(free.max_abs_diff(&rep) < 1e-4);
    }

    #[test]
    fn profiles_diverge_on_adversarial_matmul() {
        // the paper's §3.1 phenomenon: same program, different "hardware",
        // different bits.
        let a = adversarial(&[8, 256], 3);
        let b = adversarial(&[256, 8], 4);
        let t4 = matmul(&a, &b, &HardwareProfile::T4_16G);
        let a100 = matmul(&a, &b, &HardwareProfile::A100_40G);
        let a100b = matmul(&a, &b, &HardwareProfile::A100_40G);
        assert!(t4.bit_eq(&t4), "self-consistency");
        assert!(a100.bit_eq(&a100b), "per-device determinism");
        assert!(!t4.bit_eq(&a100), "cross-device divergence expected");
    }

    #[test]
    fn repops_profile_invariant_where_baseline_is_not() {
        let a = adversarial(&[4, 512], 5);
        let b = adversarial(&[512, 4], 6);
        let rep = repops::matmul(&a, &b);
        for hw in &HardwareProfile::ALL {
            let rep2 = repops::matmul(&a, &b);
            assert!(rep.bit_eq(&rep2), "repops ignores {}", hw.name);
        }
        let free: Vec<Tensor> = HardwareProfile::ALL
            .iter()
            .map(|hw| matmul(&a, &b, hw))
            .collect();
        let any_diverge = free.windows(2).any(|w| !w[0].bit_eq(&w[1]));
        assert!(any_diverge, "baseline should diverge across profiles");
    }

    #[test]
    fn sum_diverges_across_profiles_but_is_stable_per_profile() {
        let xs = adversarial(&[4096], 7);
        let mut seen = Vec::new();
        for hw in &HardwareProfile::ALL {
            let s1 = sum_slice(xs.data(), hw);
            let s2 = sum_slice(xs.data(), hw);
            assert_eq!(s1.to_bits(), s2.to_bits(), "{} self-consistent", hw.name);
            seen.push(s1.to_bits());
        }
        seen.dedup();
        assert!(seen.len() > 1, "expected ≥2 distinct sums, got {seen:?}");
    }

    #[test]
    fn combine_orders_differ() {
        // seq: ((1e8+1)-1e8)+1 = 1 (the +1 survives the first rounding);
        // tree: (1e8+1)+(-1e8+1) = 1e8 + (-1e8) = 0 (both +1s rounded away);
        // rev:  ((1+(-1e8))+1)+1e8 = 0 (both +1s rounded away).
        let p = vec![1.0e8f32, 1.0, -1.0e8, 1.0];
        assert_eq!(combine(&mut p.clone(), CombineOrder::Sequential), 1.0);
        assert_eq!(combine(&mut p.clone(), CombineOrder::PairwiseTree), 0.0);
        assert_eq!(combine(&mut p.clone(), CombineOrder::ReverseSequential), 0.0);
        // a vector where reverse differs from sequential:
        // seq: ((1-1e8)+1)+1e8 = 0 ; rev: ((1e8+1)+(-1e8))+1 = 1.
        let q = vec![1.0f32, -1.0e8, 1.0, 1.0e8];
        assert_eq!(combine(&mut q.clone(), CombineOrder::Sequential), 0.0);
        assert_eq!(combine(&mut q.clone(), CombineOrder::ReverseSequential), 1.0);
    }

    #[test]
    fn baseline_softmax_close_to_repops() {
        let a = Tensor::rand([4, 64], 8, 6.0);
        for hw in &HardwareProfile::ALL {
            let f = softmax_lastdim(&a, hw);
            let r = repops::softmax_lastdim(&a);
            assert!(f.max_abs_diff(&r) < 1e-5, "{}", hw.name);
        }
    }

    #[test]
    fn baseline_norms_close_to_repops() {
        let a = Tensor::rand([4, 96], 9, 2.0);
        let g = Tensor::rand([96], 10, 1.0);
        let b = Tensor::rand([96], 11, 1.0);
        let hw = HardwareProfile::RTX3090_24G;
        assert!(layernorm(&a, &g, &b, 1e-5, &hw)
            .max_abs_diff(&repops::layernorm(&a, &g, &b, 1e-5))
            < 1e-4);
        assert!(rmsnorm(&a, &g, 1e-6, &hw).max_abs_diff(&repops::rmsnorm(&a, &g, 1e-6)) < 1e-4);
    }

    #[test]
    fn vendor_activations_close_to_repops() {
        let a = Tensor::rand([256], 12, 4.0);
        assert!(gelu(&a).max_abs_diff(&repops::gelu(&a)) < 1e-5);
        assert!(silu(&a).max_abs_diff(&repops::silu(&a)) < 1e-5);
    }

    #[test]
    fn bmm_matches_matmul_per_batch() {
        let a = Tensor::rand([2, 3, 4], 13, 1.0);
        let b = Tensor::rand([2, 4, 5], 14, 1.0);
        let hw = HardwareProfile::A100_80G;
        let c = bmm(&a, &b, &hw);
        let a0 = Tensor::new([3, 4], a.data()[..12].to_vec());
        let b0 = Tensor::new([4, 5], b.data()[..20].to_vec());
        let c0 = matmul(&a0, &b0, &hw);
        assert_eq!(&c.data()[..15], c0.data());
    }
}
