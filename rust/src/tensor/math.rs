//! Reproducible scalar math: fixed-evaluation-order implementations of the
//! transcendental functions neural networks need (paper §3.1: RepOps
//! "re-implements common ML operators and mathematical functions like exp,
//! sin, cos, tanh").
//!
//! `libm` implementations differ between platforms/versions, so RepOps cannot
//! call them. Every function here is a fixed sequence of IEEE-754 single
//! operations (add/mul/div/sqrt are correctly rounded and therefore
//! bit-deterministic on every compliant implementation); polynomials are
//! evaluated in Horner form, which fixes the operation order syntactically.
//! Rust never licenses FP reassociation or contraction (no implicit FMA), so
//! the compiled order equals the source order.
//!
//! Accuracy targets are a few ULP — plenty for training parity — and are
//! checked against `std` libm in the tests. Determinism, not last-bit
//! accuracy, is the contract.

/// ln(2) split Cody–Waite style: `LN2_HI + LN2_LO ≈ ln 2` with `LN2_HI`
/// having enough trailing zero bits that `n * LN2_HI` is exact for |n| < 2^8.
const LN2_HI: f32 = 0.693_145_751_953_125; // 0x1.62e4p-1
const LN2_LO: f32 = 1.428_606_765_330_187_e-6; // ln2 - LN2_HI
const LOG2_E: f32 = 1.442_695_040_888_963_4;

/// Reproducible `exp(x)` for f32.
///
/// Range-reduce `x = n·ln2 + r`, `|r| ≤ ln2/2`, evaluate a degree-5
/// minimax-ish polynomial of `e^r` in Horner form, then scale by `2^n`
/// through exponent-bit arithmetic (exact).
pub fn rep_exp(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    if x > 88.72 {
        return f32::INFINITY;
    }
    if x < -87.33 {
        return 0.0;
    }
    // n = round(x / ln2)
    let n = (x * LOG2_E).round_ties_even();
    let r = (x - n * LN2_HI) - n * LN2_LO;
    // e^r ≈ 1 + r + r²/2! + r³/3! + r⁴/4! + r⁵/5!  (|r| ≤ 0.347 ⇒ ~1e-8 rel)
    let p = {
        let c5 = 1.0 / 120.0;
        let c4 = 1.0 / 24.0;
        let c3 = 1.0 / 6.0;
        let c2 = 0.5;
        ((((c5 * r + c4) * r + c3) * r + c2) * r + 1.0) * r + 1.0
    };
    scale_by_pow2(p, n as i32)
}

/// Exact multiplication by 2^n via exponent bits, handling subnormal spill
/// by splitting the scale.
#[inline]
fn scale_by_pow2(x: f32, n: i32) -> f32 {
    // Clamp to the representable exponent window, splitting in two steps so
    // intermediate scales stay normal.
    let step = |x: f32, n: i32| -> f32 {
        let n = n.clamp(-126, 127);
        x * f32::from_bits(((127 + n) as u32) << 23)
    };
    if (-126..=127).contains(&n) {
        step(x, n)
    } else if n > 0 {
        step(step(x, 127), n - 127)
    } else {
        step(step(x, -126), n + 126)
    }
}

/// Reproducible natural log.
///
/// Decompose `x = m·2^e`, `m ∈ [√2/2, √2)`; `ln m` via the `atanh` series in
/// `s = (m-1)/(m+1)`:  `ln m = 2s + 2s³/3 + 2s⁵/5 + …` (Horner in `s²`).
pub fn rep_ln(x: f32) -> f32 {
    if x.is_nan() || x < 0.0 {
        return f32::NAN;
    }
    if x == 0.0 {
        return f32::NEG_INFINITY;
    }
    if x.is_infinite() {
        return f32::INFINITY;
    }
    let bits = x.to_bits();
    let mut e = ((bits >> 23) as i32) - 127;
    let mut m = f32::from_bits((bits & 0x007f_ffff) | 0x3f80_0000); // [1,2)
    // subnormals: normalize first
    if e == -127 {
        let xn = x * f32::from_bits((127 + 24) << 23); // x * 2^24, exact
        let nb = xn.to_bits();
        e = ((nb >> 23) as i32) - 127 - 24;
        m = f32::from_bits((nb & 0x007f_ffff) | 0x3f80_0000);
    }
    const SQRT2: f32 = 1.414_213_562_373_095_1;
    if m >= SQRT2 {
        m *= 0.5;
        e += 1;
    }
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    // 2·(s + s³/3 + s⁵/5 + s⁷/7 + s⁹/9)
    let p = (((s2 / 9.0 + 1.0 / 7.0) * s2 + 1.0 / 5.0) * s2 + 1.0 / 3.0) * s2 + 1.0;
    let ef = e as f32;
    (ef * LN2_HI + ef * LN2_LO) + 2.0 * s * p
}

/// Reproducible `tanh` via `rep_exp`: `tanh x = 1 − 2/(e^{2x}+1)` for x ≥ 0,
/// odd-extended for x < 0. Saturates exactly to ±1 beyond |x| > 9.
pub fn rep_tanh(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let neg = x.is_sign_negative(); // preserves -0.0 → -0.0 (IEEE tanh)
    let ax = if neg { -x } else { x };
    if ax > 9.02 {
        return if neg { -1.0 } else { 1.0 };
    }
    let t = 1.0 - 2.0 / (rep_exp(2.0 * ax) + 1.0);
    if neg {
        -t
    } else {
        t
    }
}

/// Reproducible logistic sigmoid `1/(1+e^{-x})`, evaluated on the
/// numerically stable branch for each sign so it is monotone and bounded.
pub fn rep_sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + rep_exp(-x))
    } else {
        let e = rep_exp(x);
        e / (1.0 + e)
    }
}

/// Reproducible `erf` (Abramowitz & Stegun 7.1.26; |ε| ≤ 1.5e-7).
pub fn rep_erf(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let ax = sign * x;
    if ax > 4.0 {
        return sign; // erf saturates well within f32 below 4
    }
    const A1: f32 = 0.254_829_592;
    const A2: f32 = -0.284_496_736;
    const A3: f32 = 1.421_413_741;
    const A4: f32 = -1.453_152_027;
    const A5: f32 = 1.061_405_429;
    const P: f32 = 0.327_591_1;
    let t = 1.0 / (1.0 + P * ax);
    let poly = ((((A5 * t + A4) * t + A3) * t + A2) * t + A1) * t;
    let y = 1.0 - poly * rep_exp(-(ax * ax));
    sign * y
}

/// Exact-GELU (the DistilBERT/BERT activation): `0.5·x·(1 + erf(x/√2))`.
pub fn rep_gelu(x: f32) -> f32 {
    const INV_SQRT2: f32 = 0.707_106_781_186_547_6;
    0.5 * x * (1.0 + rep_erf(x * INV_SQRT2))
}

/// SiLU / swish (the Llama activation): `x · sigmoid(x)`.
pub fn rep_silu(x: f32) -> f32 {
    x * rep_sigmoid(x)
}

/// Reproducible sine for the bounded arguments RoPE produces (|x| ≤ ~2^13).
/// Cody–Waite reduction mod π/2 then degree-7/6 Taylor–Horner kernels.
pub fn rep_sin(x: f32) -> f32 {
    let (q, r) = reduce_pi_2(x);
    match q & 3 {
        0 => sin_kernel(r),
        1 => cos_kernel(r),
        2 => -sin_kernel(r),
        _ => -cos_kernel(r),
    }
}

/// Reproducible cosine (see [`rep_sin`]).
pub fn rep_cos(x: f32) -> f32 {
    let (q, r) = reduce_pi_2(x);
    match q & 3 {
        0 => cos_kernel(r),
        1 => -sin_kernel(r),
        2 => -cos_kernel(r),
        _ => sin_kernel(r),
    }
}

/// Argument reduction `x = q·(π/2) + r`, |r| ≤ π/4, Cody–Waite two-part π/2.
/// Accurate for |x| ≲ 2^13 — RoPE angles are ≤ max-position, far below that.
fn reduce_pi_2(x: f32) -> (i32, f32) {
    const PI2_HI: f32 = 1.570_796_251_296_997_1; // 0x1.921fb4p0
    const PI2_LO: f32 = 7.549_789_415_861_596e-8;
    let q = (x * (1.0 / (PI2_HI + PI2_LO))).round_ties_even();
    let r = (x - q * PI2_HI) - q * PI2_LO;
    (q as i32, r)
}

#[inline]
fn sin_kernel(r: f32) -> f32 {
    // sin r ≈ r − r³/3! + r⁵/5! − r⁷/7!
    let r2 = r * r;
    ((( -1.0 / 5040.0 * r2 + 1.0 / 120.0) * r2 - 1.0 / 6.0) * r2 + 1.0) * r
}

#[inline]
fn cos_kernel(r: f32) -> f32 {
    // cos r ≈ 1 − r²/2! + r⁴/4! − r⁶/6! + r⁸/8!
    let r2 = r * r;
    (((1.0 / 40320.0 * r2 - 1.0 / 720.0) * r2 + 1.0 / 24.0) * r2 - 0.5) * r2 + 1.0
}

/// `sqrt` — IEEE-754 requires correct rounding, so the hardware instruction
/// is already bit-deterministic; exposed for symmetry/clarity at call sites.
#[inline]
pub fn rep_sqrt(x: f32) -> f32 {
    x.sqrt()
}

/// `1/√x` composed from two correctly-rounded ops (NOT the fast-rsqrt
/// intrinsic, whose precision differs per architecture).
#[inline]
pub fn rep_rsqrt(x: f32) -> f32 {
    1.0 / x.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep(lo: f32, hi: f32, n: usize) -> impl Iterator<Item = f32> {
        (0..=n).map(move |i| lo + (hi - lo) * i as f32 / n as f32)
    }

    #[test]
    fn exp_matches_libm() {
        for x in sweep(-80.0, 80.0, 40_000) {
            let got = rep_exp(x);
            let want = x.exp();
            let rel = if want == 0.0 { got.abs() } else { ((got - want) / want).abs() };
            assert!(rel < 4e-6, "exp({x}) = {got}, libm {want}, rel {rel}");
        }
    }

    #[test]
    fn exp_edge_cases() {
        assert_eq!(rep_exp(0.0), 1.0);
        assert_eq!(rep_exp(f32::INFINITY), f32::INFINITY);
        assert_eq!(rep_exp(f32::NEG_INFINITY), 0.0);
        assert_eq!(rep_exp(100.0), f32::INFINITY);
        assert_eq!(rep_exp(-100.0), 0.0);
        assert!(rep_exp(f32::NAN).is_nan());
    }

    #[test]
    fn ln_matches_libm() {
        for x in sweep(1e-30, 1e4, 40_000).chain(sweep(1e-4, 2.0, 10_000)) {
            if x <= 0.0 {
                continue;
            }
            let got = rep_ln(x);
            let want = x.ln();
            let tol = 1e-6_f32.max(want.abs() * 2e-6);
            assert!((got - want).abs() < tol, "ln({x}) = {got}, libm {want}");
        }
    }

    #[test]
    fn ln_exp_roundtrip() {
        for x in sweep(-10.0, 10.0, 1000) {
            let got = rep_ln(rep_exp(x));
            assert!((got - x).abs() < 1e-5 * x.abs().max(1.0), "ln(exp({x})) = {got}");
        }
    }

    #[test]
    fn ln_edge_cases() {
        assert_eq!(rep_ln(1.0), 0.0);
        assert_eq!(rep_ln(0.0), f32::NEG_INFINITY);
        assert!(rep_ln(-1.0).is_nan());
        assert_eq!(rep_ln(f32::INFINITY), f32::INFINITY);
        // subnormal input
        let sub = f32::from_bits(1);
        assert!((rep_ln(sub) - sub.ln()).abs() < 1e-4);
    }

    #[test]
    fn tanh_matches_libm() {
        for x in sweep(-12.0, 12.0, 20_000) {
            let got = rep_tanh(x);
            let want = x.tanh();
            assert!((got - want).abs() < 3e-6, "tanh({x}) = {got}, libm {want}");
        }
        assert_eq!(rep_tanh(50.0), 1.0);
        assert_eq!(rep_tanh(-50.0), -1.0);
    }

    #[test]
    fn tanh_is_odd_bitwise() {
        for x in sweep(0.0, 10.0, 5000) {
            assert_eq!(rep_tanh(-x).to_bits(), (-rep_tanh(x)).to_bits());
        }
    }

    #[test]
    fn erf_matches_reference() {
        // reference values from double-precision erf
        let cases: [(f32, f32); 7] = [
            (0.0, 0.0),
            (0.5, 0.5204999),
            (1.0, 0.8427008),
            (1.5, 0.9661051),
            (2.0, 0.9953223),
            (3.0, 0.9999779),
            (-1.0, -0.8427008),
        ];
        for (x, want) in cases {
            let got = rep_erf(x);
            assert!((got - want).abs() < 2e-6, "erf({x}) = {got}, want {want}");
        }
    }

    #[test]
    fn sigmoid_properties() {
        assert_eq!(rep_sigmoid(0.0), 0.5);
        for x in sweep(-30.0, 30.0, 10_000) {
            let s = rep_sigmoid(x);
            assert!((0.0..=1.0).contains(&s));
            let want = 1.0 / (1.0 + (-x).exp());
            assert!((s - want).abs() < 3e-6, "sigmoid({x}) = {s}, want {want}");
        }
    }

    #[test]
    fn gelu_silu_spot_checks() {
        // torch reference values
        assert!((rep_gelu(1.0) - 0.8413447).abs() < 1e-5);
        assert!((rep_gelu(-1.0) - (-0.15865526)).abs() < 1e-5);
        assert!((rep_silu(1.0) - 0.7310586).abs() < 1e-5);
        assert_eq!(rep_gelu(0.0), 0.0);
        assert_eq!(rep_silu(0.0), 0.0);
    }

    #[test]
    fn sin_cos_match_libm_on_rope_range() {
        for x in sweep(-4096.0, 4096.0, 100_000) {
            let (gs, gc) = (rep_sin(x), rep_cos(x));
            let (ws, wc) = (x.sin(), x.cos());
            assert!((gs - ws).abs() < 3e-4, "sin({x}) = {gs}, libm {ws}");
            assert!((gc - wc).abs() < 3e-4, "cos({x}) = {gc}, libm {wc}");
        }
        // tighter check near zero where RoPE's high-frequency dims live
        for x in sweep(-3.2, 3.2, 10_000) {
            assert!((rep_sin(x) - x.sin()).abs() < 2e-6);
            assert!((rep_cos(x) - x.cos()).abs() < 2e-6);
        }
    }

    #[test]
    fn determinism_bitwise() {
        // same input -> same bits, across calls (trivially true in one
        // process, but guards against accidental statics/rng).
        for x in sweep(-5.0, 5.0, 1000) {
            assert_eq!(rep_exp(x).to_bits(), rep_exp(x).to_bits());
            assert_eq!(rep_tanh(x).to_bits(), rep_tanh(x).to_bits());
            assert_eq!(rep_erf(x).to_bits(), rep_erf(x).to_bits());
        }
    }

    #[test]
    fn scale_by_pow2_extremes() {
        assert_eq!(scale_by_pow2(1.0, 10), 1024.0);
        assert_eq!(scale_by_pow2(1.0, -10), 1.0 / 1024.0);
        assert_eq!(scale_by_pow2(1.5, 0), 1.5);
        // deep subnormal round-trip
        let tiny = scale_by_pow2(1.0, -140);
        assert!(tiny > 0.0 && tiny < f32::MIN_POSITIVE);
    }
}
