//! Simulated hardware profiles (DESIGN.md §4, substitution 1).
//!
//! The paper's source of nondeterminism is that different GPU architectures
//! schedule the partial sums of a reduction differently: more SMs / different
//! warp widths ⇒ a different floating-point combination tree, hence (by
//! non-associativity of FP addition) different bits for the *same* program.
//!
//! We reify "the architecture-dependent part of the schedule" as a
//! [`HardwareProfile`]. Baseline (free-order) operators consult it to decide
//! how a reduction is chunked and in which order partial results combine;
//! RepOps operators ignore it entirely. Each profile is internally
//! deterministic — running twice on the same profile gives the same bits,
//! just as a given GPU is (usually) self-consistent — but profiles differ
//! from each other, which is exactly the cross-hardware setting of §3.1.

use std::time::Instant;

/// Gated wall-clock timer for one operator execution. When
/// [`crate::obs::enable_kernel_timing`] has been called, the elapsed time
/// lands in the process-global registry as a `repops_*_us` histogram
/// (plus a `repops_ops` counter); otherwise starting it is a single
/// relaxed atomic load and stopping is a no-op, so the training hot loop
/// pays nothing while the timer is dormant.
///
/// The timer brackets the whole operator on the *submitting* thread, so on
/// the data-parallel path (see `util::parallel`) it measures wall-clock
/// including fan-out and the completion barrier — not summed per-thread
/// CPU time. That is deliberate: the histograms then show multicore
/// speedup directly, and attribution stays on the one op the submitter is
/// executing (pool workers never start timers of their own).
pub struct KernelTimer {
    start: Option<Instant>,
}

impl KernelTimer {
    /// Arm the timer iff kernel timing is enabled.
    pub fn start() -> KernelTimer {
        KernelTimer {
            start: crate::obs::kernel_timing_enabled().then(Instant::now),
        }
    }

    /// Record the elapsed time under `key` (e.g. `repops_matmul_us`).
    pub fn stop(self, key: &'static str) {
        if let Some(t0) = self.start {
            let g = crate::obs::global();
            g.counter("repops_ops").inc();
            g.histogram(key, &crate::obs::LATENCY_US_BOUNDS).observe_micros(t0.elapsed());
        }
    }
}

/// An execution-environment fingerprint: the knobs of a reduction schedule
/// that, on real hardware, are fixed by the silicon + library version.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HardwareProfile {
    /// Human-readable device name (mirrors the paper's four test GPUs).
    pub name: &'static str,
    /// Number of independent accumulation lanes a reduction is split across
    /// (the analogue of how many threads/warps cuDNN assigns to the K loop).
    pub lanes: usize,
    /// Combination tree for the per-lane partials.
    pub combine: CombineOrder,
    /// Simulated device memory in bytes — used by the model benches to decide
    /// feasible batch sizes, mirroring the paper's VRAM-driven observations.
    pub vram_bytes: u64,
    /// Relative throughput multiplier of the simulated device, used only for
    /// reporting projected wall-clock in EXPERIMENTS.md (never for numerics).
    pub rel_throughput: f64,
}

/// Order in which per-lane partial sums are combined.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CombineOrder {
    /// `((p0 + p1) + p2) + p3 …` — lane-ascending left fold.
    Sequential,
    /// Balanced pairwise tree: `(p0+p1) + (p2+p3) …`.
    PairwiseTree,
    /// Lane-descending fold — models a device that retires high lanes first.
    ReverseSequential,
}

impl HardwareProfile {
    /// NVIDIA T4 (16 GB) stand-in: few lanes, sequential combine.
    pub const T4_16G: HardwareProfile = HardwareProfile {
        name: "T4-16G",
        lanes: 4,
        combine: CombineOrder::Sequential,
        vram_bytes: 16 << 30,
        rel_throughput: 1.0,
    };

    /// NVIDIA RTX 3090 (24 GB) stand-in.
    pub const RTX3090_24G: HardwareProfile = HardwareProfile {
        name: "RTX3090-24G",
        lanes: 8,
        combine: CombineOrder::PairwiseTree,
        vram_bytes: 24 << 30,
        rel_throughput: 2.2,
    };

    /// NVIDIA A100 (40 GB) stand-in.
    pub const A100_40G: HardwareProfile = HardwareProfile {
        name: "A100-40G",
        lanes: 16,
        combine: CombineOrder::PairwiseTree,
        vram_bytes: 40 << 30,
        rel_throughput: 4.0,
    };

    /// NVIDIA A100 (80 GB) stand-in.
    pub const A100_80G: HardwareProfile = HardwareProfile {
        name: "A100-80G",
        lanes: 16,
        combine: CombineOrder::ReverseSequential,
        vram_bytes: 80 << 30,
        rel_throughput: 4.2,
    };

    /// The paper's full device matrix (§4).
    pub const ALL: [HardwareProfile; 4] = [
        Self::T4_16G,
        Self::RTX3090_24G,
        Self::A100_40G,
        Self::A100_80G,
    ];
}

impl Default for HardwareProfile {
    fn default() -> Self {
        Self::A100_40G
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_are_distinct() {
        for (i, a) in HardwareProfile::ALL.iter().enumerate() {
            for b in &HardwareProfile::ALL[i + 1..] {
                assert_ne!(a, b);
                // distinct reduction schedules, not just names:
                assert!(
                    a.lanes != b.lanes || a.combine != b.combine,
                    "{} and {} share a reduction schedule",
                    a.name,
                    b.name
                );
            }
        }
    }
}
