//! RepOps — bitwise-reproducible ML operators (paper §3).
//!
//! Every function in this module computes its result through a floating-point
//! operation sequence that is a pure function of the *program* (shapes and
//! source order), never of the executing hardware:
//!
//! * reductions (matmul K-loop, sums, means, variances) run in a fixed
//!   ascending index order — the paper's "serialize the order-critical
//!   dimension" rule (§3.2). The order-insensitive dimensions (M, N, batch,
//!   rows) remain free for the compiler/hardware to vectorize, which is where
//!   the performance comes from;
//! * no fused multiply-add: FMA skips the intermediate rounding and is not
//!   available (or not used identically) on all hardware, so RepOps always
//!   performs separately-rounded IEEE mul and add. Rust guarantees no
//!   implicit contraction or reassociation, so source order == machine order;
//! * transcendental functions come from [`super::math`] (fixed Horner
//!   evaluation), never libm.
//!
//! The matching free-order implementations, whose bits legitimately vary by
//! [`HardwareProfile`](super::profile::HardwareProfile), live in
//! [`super::baseline`]; the two share shape-checking helpers so benches
//! compare like for like.
//!
//! **Data parallelism.** Every kernel here fans its order-*insensitive*
//! dimensions (M rows, N panels, batch, independent output rows/elements)
//! out to the persistent pool in [`crate::util::parallel`]; the
//! order-critical dimension of each reduction stays a single fixed-order
//! loop inside one chunk body. Results are bitwise identical for every
//! thread count — partitioning is a pure function of shape, each output
//! element is produced by exactly one unchanged scalar recipe, and chunks
//! write disjoint output rows. `tests/par_invariance.rs` pins this across
//! thread counts {1, 2, 3, 8} up to trainer checkpoint roots. The
//! free-order [`super::baseline`] deliberately stays single-core: it
//! simulates a *reduction schedule*, not wall-clock, and keeping it serial
//! preserves the seeded overhead-benchmark baseline.

use std::cell::RefCell;

use super::math;
use super::Tensor;
use crate::util::parallel;

// ---------------------------------------------------------------------------
// shape helpers (shared with baseline via pub(crate))
// ---------------------------------------------------------------------------

/// Check and destructure `[m,k] x [k,n]` matmul shapes.
pub(crate) fn mm_dims(a: &Tensor, b: &Tensor) -> (usize, usize, usize) {
    assert_eq!(a.rank(), 2, "matmul lhs must be rank-2, got {:?}", a.shape());
    assert_eq!(b.rank(), 2, "matmul rhs must be rank-2, got {:?}", b.shape());
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {:?} x {:?}", a.shape(), b.shape());
    (m, k, n)
}

/// Check and destructure batched `[b,m,k] x [b,k,n]` shapes.
pub(crate) fn bmm_dims(a: &Tensor, b: &Tensor) -> (usize, usize, usize, usize) {
    assert_eq!(a.rank(), 3, "bmm lhs must be rank-3, got {:?}", a.shape());
    assert_eq!(b.rank(), 3, "bmm rhs must be rank-3, got {:?}", b.shape());
    let (ba, m, k) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let (bb, k2, n) = (b.shape()[0], b.shape()[1], b.shape()[2]);
    assert_eq!(ba, bb, "bmm batch dims: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(k, k2, "bmm inner dims: {:?} x {:?}", a.shape(), b.shape());
    (ba, m, k, n)
}

/// Rows/cols view of the trailing dimension: `[..., n]` as `(rows, n)`.
pub(crate) fn rows_lastdim(t: &Tensor) -> (usize, usize) {
    assert!(t.rank() >= 1);
    let n = *t.shape().last().unwrap();
    (t.numel() / n, n)
}

// ---------------------------------------------------------------------------
// matmul family
// ---------------------------------------------------------------------------

/// Reproducible `[m,k] x [k,n]` matrix multiplication.
///
/// Loop order is `i → k → j`: the inner `j` loop vectorizes freely (each
/// lane is an independent output element), while for any fixed `(i,j)` the
/// K-dimension partial sums accumulate in strictly ascending `k` — the same
/// reduction tree as the paper's reference pseudo-code in §3.2 and as the
/// Pallas kernel in `python/compile/kernels/repmatmul.py`.
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = mm_dims(a, b);
    let mut c = vec![0.0f32; m * n];
    matmul_into(a.data(), b.data(), &mut c, m, k, n);
    Tensor::new([m, n], c)
}

/// Register-tile width of the j panel (4 AVX2 vectors).
const JB: usize = 32;

/// K block size: B sub-panel (KB × JB × 4 B = 32 KiB) stays L1-resident.
const KB: usize = 256;

thread_local! {
    /// Per-thread packed-B scratch for the matmul kernel: allocated once
    /// per thread (main or pool worker) and reused across every call, so
    /// the hot path performs no allocation. Only the prefix written for
    /// the current (panel, K-block) tile is ever read back.
    static PACK: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// Hand the caller this thread's packed-B scratch, growing it on first use.
fn with_pack<R>(f: impl FnOnce(&mut [f32]) -> R) -> R {
    PACK.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < KB * JB {
            p.resize(KB * JB, 0.0);
        }
        f(&mut p[..KB * JB])
    })
}

/// One thread's rectangle of the matmul: rows `i0..i1` × columns `j0..j1`
/// of the `[m, n]` output, each element accumulated over the full K range.
///
/// Blocked `jb → kb(ascending, required for order) → i` schedule with a
/// `JB`-wide register accumulator reloaded from C between K blocks.
/// Reloading a partial sum through memory does not change its bits, and kb
/// blocks retire in ascending order, so every element still accumulates
/// term-by-term in ascending k — bitwise equal to the naive i-j-k
/// pseudo-code (checked in the tests); blocking and the rectangle split
/// only re-order *independent* elements. `FMA=false` → separately-rounded
/// mul+add (the portable §3.2 contract); `FMA=true` → single-rounded fused
/// contract (matches XLA/FFMA, see [`matmul_fma`]).
///
/// The B sub-panel is packed contiguously into `pack`: kills the
/// large-stride cache-set conflicts of walking `b[(kb+kk)*n + jb]` and
/// gives the inner loop pure unit-stride loads. Packing is a copy — bits
/// are untouched. `j0` is always a multiple of `JB`, so panel boundaries
/// are identical to the serial schedule (irrelevant for bits, tidy for
/// perf comparisons).
///
/// # Safety
/// `c` must point at the full `[m, n]` output buffer, and no other thread
/// may concurrently touch the `[i0..i1) × [j0..j1)` rectangle. Callers
/// split the output into disjoint rectangles by construction.
#[allow(clippy::too_many_arguments)]
unsafe fn mm_rect<const FMA: bool>(
    a: &[f32],
    b: &[f32],
    c: *mut f32,
    k: usize,
    n: usize,
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    pack: &mut [f32],
) {
    let mut jb = j0;
    while jb < j1 {
        let w = JB.min(j1 - jb);
        let mut kb = 0;
        while kb < k {
            let kw = KB.min(k - kb);
            for kk in 0..kw {
                pack[kk * w..kk * w + w]
                    .copy_from_slice(&b[(kb + kk) * n + jb..(kb + kk) * n + jb + w]);
            }
            for i in i0..i1 {
                let arow = &a[i * k + kb..i * k + kb + kw];
                // SAFETY: the (i, jb..jb+w) row segment lies inside this
                // call's exclusive rectangle (see function contract).
                let crow = unsafe { std::slice::from_raw_parts_mut(c.add(i * n + jb), w) };
                if w == JB {
                    let mut acc = [0.0f32; JB];
                    acc.copy_from_slice(crow);
                    for (kk, &aik) in arow.iter().enumerate() {
                        let brow = &pack[kk * JB..kk * JB + JB];
                        for j in 0..JB {
                            if FMA {
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            } else {
                                acc[j] += aik * brow[j];
                            }
                        }
                    }
                    crow.copy_from_slice(&acc);
                } else {
                    // remainder panel (j1 - jb < JB)
                    let mut accbuf = [0.0f32; JB];
                    let acc = &mut accbuf[..w];
                    acc.copy_from_slice(crow);
                    for (kk, &aik) in arow.iter().enumerate() {
                        let brow = &pack[kk * w..kk * w + w];
                        for j in 0..w {
                            if FMA {
                                acc[j] = aik.mul_add(brow[j], acc[j]);
                            } else {
                                acc[j] += aik * brow[j];
                            }
                        }
                    }
                    crow.copy_from_slice(acc);
                }
            }
            kb += kw;
        }
        jb += w;
    }
}

/// Core of [`matmul`] on raw slices; also used by the batched variant.
///
/// Fans the order-insensitive dimensions out to the worker pool: i-row
/// blocks when there are enough rows to feed every thread, j-panel blocks
/// otherwise (tall-skinny / vector-matrix shapes). Each chunk runs
/// [`mm_rect`] on a disjoint output rectangle with this thread's packed-B
/// scratch; per-element ascending-k accumulation is untouched in both
/// contracts, so the result is bitwise identical at every thread count.
#[inline]
pub(crate) fn mm_kernel<const FMA: bool>(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let t = parallel::threads();
    let cp = parallel::SendPtr::new(c.as_mut_ptr());
    let total = m * k * n;
    let panels = n.div_ceil(JB);
    if t <= 1 || total < 2 * parallel::MM_GRAIN {
        with_pack(|pack| unsafe { mm_rect::<FMA>(a, b, cp.get(), k, n, 0, m, 0, n, pack) });
    } else if m >= t || panels < 2 {
        // split over i-row blocks; each chunk covers all columns
        let min_rows = (parallel::MM_GRAIN / (k * n).max(1)).max(1);
        parallel::for_each_chunk(m, min_rows, |r| {
            with_pack(|pack| unsafe {
                mm_rect::<FMA>(a, b, cp.get(), k, n, r.start, r.end, 0, n, pack)
            });
        });
    } else {
        // few rows: split over j panels; each chunk covers all rows
        let min_panels = (parallel::MM_GRAIN / (m * k * JB).max(1)).max(1);
        parallel::for_each_chunk(panels, min_panels, |pr| {
            let j0 = pr.start * JB;
            let j1 = (pr.end * JB).min(n);
            with_pack(|pack| unsafe {
                mm_rect::<FMA>(a, b, cp.get(), k, n, 0, m, j0, j1, pack)
            });
        });
    }
}

#[inline]
pub(crate) fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    mm_kernel::<false>(a, b, c, m, k, n);
}

/// Reproducible matmul under the **FMA contract**: identical loop/order to
/// [`matmul`], but each `k` term is folded with a single-rounded fused
/// multiply-add. This matches what XLA (and CUDA FFMA) emit for the Layer-1
/// Pallas kernel, so it is the variant used for cross-backend bitwise
/// parity with the AOT artifacts. Requires FMA hardware to be fast — the
/// portability trade-off §3.3 alludes to; the separate-rounding [`matmul`]
/// is the conservative default for the protocol engine.
pub fn matmul_fma(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k, n) = mm_dims(a, b);
    let mut c = vec![0.0f32; m * n];
    mm_kernel::<true>(a.data(), b.data(), &mut c, m, k, n);
    Tensor::new([m, n], c)
}

/// Reproducible batched matmul `[b,m,k] x [b,k,n] -> [b,m,n]`.
///
/// The batch dimension is fully order-insensitive, so batches fan out to
/// the pool; each batch entry runs the *serial* rectangle kernel inside
/// its chunk (nesting a parallel region per batch would only add overhead,
/// and the inline fallback makes it bitwise-equivalent anyway).
pub fn bmm(a: &Tensor, b: &Tensor) -> Tensor {
    let (bs, m, k, n) = bmm_dims(a, b);
    let mut c = vec![0.0f32; bs * m * n];
    let per_batch = m * k * n;
    let cp = parallel::SendPtr::new(c.as_mut_ptr());
    let min_batches = (parallel::MM_GRAIN / per_batch.max(1)).max(1);
    let (ad, bd) = (a.data(), b.data());
    parallel::for_each_chunk(bs, min_batches, |r| {
        for ib in r {
            with_pack(|pack| unsafe {
                // SAFETY: batch ib's [m, n] output block is touched by
                // exactly one chunk; blocks are disjoint.
                mm_rect::<false>(
                    &ad[ib * m * k..(ib + 1) * m * k],
                    &bd[ib * k * n..(ib + 1) * k * n],
                    cp.get().add(ib * m * n),
                    k,
                    n,
                    0,
                    m,
                    0,
                    n,
                    pack,
                )
            });
        }
    });
    Tensor::new([bs, m, n], c)
}

/// Tile side for the cache-blocked transposes: a 32×32 f32 tile is 4 KiB,
/// so source rows and destination columns both stay L1-resident while the
/// tile is copied, instead of every store missing at transformer shapes.
const TB: usize = 32;

/// Transpose the `[m, n]` block at `src` into the `[n, m]` block at `dst`,
/// walking TB×TB tiles. Pure data movement — bits are copied, never
/// computed — so tiling and threading cannot change the result.
fn transpose_block_into(src: &[f32], dst: &mut [f32], m: usize, n: usize) {
    let dp = parallel::SendPtr::new(dst.as_mut_ptr());
    // chunk over row-tiles: each chunk writes dst columns i0.., disjoint
    let row_tiles = m.div_ceil(TB);
    let min_tiles = (parallel::EW_GRAIN / (TB * n).max(1)).max(1);
    parallel::for_each_chunk(row_tiles, min_tiles, |tr| {
        for ti in tr {
            let i0 = ti * TB;
            let i1 = (i0 + TB).min(m);
            let mut j0 = 0;
            while j0 < n {
                let j1 = (j0 + TB).min(n);
                for i in i0..i1 {
                    for j in j0..j1 {
                        // SAFETY: dst element (j, i) with i in this chunk's
                        // exclusive i-range; chunks write disjoint columns.
                        unsafe { *dp.get().add(j * m + i) = src[i * n + j] };
                    }
                }
                j0 = j1;
            }
        }
    });
}

/// 2-D transpose (pure data movement — no FP ops, trivially reproducible).
pub fn transpose2d(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    let mut out = vec![0.0f32; m * n];
    transpose_block_into(a.data(), &mut out, m, n);
    Tensor::new([n, m], out)
}

/// Batched transpose of the two trailing dims: `[b,m,n] -> [b,n,m]`.
pub fn transpose_last2(a: &Tensor) -> Tensor {
    assert_eq!(a.rank(), 3);
    let (bs, m, n) = (a.shape()[0], a.shape()[1], a.shape()[2]);
    let mut out = vec![0.0f32; bs * m * n];
    for (ib, dst) in out.chunks_exact_mut(m * n).enumerate() {
        transpose_block_into(&a.data()[ib * m * n..(ib + 1) * m * n], dst, m, n);
    }
    Tensor::new([bs, n, m], out)
}

// ---------------------------------------------------------------------------
// elementwise family (order-insensitive per element; still fixed by source)
// ---------------------------------------------------------------------------

/// Elementwise zip of two same-shape tensors (public: backward kernels are
/// built from it). Each output element depends only on its own inputs, so
/// flat index ranges fan out to the pool; `f` runs once per element with
/// unchanged arguments regardless of thread count.
pub fn zipmap(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32 + Sync) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "elementwise shape mismatch");
    let mut out = vec![0.0f32; a.numel()];
    let (ad, bd) = (a.data(), b.data());
    parallel::for_each_row_chunk(&mut out, 1, parallel::EW_GRAIN, |first, dst| {
        for (o, i) in dst.iter_mut().zip(first..) {
            *o = f(ad[i], bd[i]);
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

fn zip_same_shape(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32 + Sync) -> Tensor {
    zipmap(a, b, f)
}

pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    zip_same_shape(a, b, |x, y| x + y)
}

pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    zip_same_shape(a, b, |x, y| x - y)
}

pub fn mul(a: &Tensor, b: &Tensor) -> Tensor {
    zip_same_shape(a, b, |x, y| x * y)
}

pub fn div(a: &Tensor, b: &Tensor) -> Tensor {
    zip_same_shape(a, b, |x, y| x / y)
}

pub fn scale(a: &Tensor, s: f32) -> Tensor {
    map(a, |x| x * s)
}

pub fn map(a: &Tensor, f: impl Fn(f32) -> f32 + Sync) -> Tensor {
    let mut out = vec![0.0f32; a.numel()];
    let ad = a.data();
    parallel::for_each_row_chunk(&mut out, 1, parallel::EW_GRAIN, |first, dst| {
        for (o, i) in dst.iter_mut().zip(first..) {
            *o = f(ad[i]);
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// `a + row` where `row` broadcasts across all leading dims: `[..., n] + [n]`.
pub fn add_row(a: &Tensor, row: &Tensor) -> Tensor {
    let (_rows, n) = rows_lastdim(a);
    assert_eq!(row.shape(), [n], "row broadcast wants [{n}], got {:?}", row.shape());
    let mut out = a.data().to_vec();
    let rd = row.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |_, dst| {
        for orow in dst.chunks_exact_mut(n) {
            for j in 0..n {
                orow[j] += rd[j];
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// `a * row`, broadcasting as in [`add_row`].
pub fn mul_row(a: &Tensor, row: &Tensor) -> Tensor {
    let (_rows, n) = rows_lastdim(a);
    assert_eq!(row.shape(), [n]);
    let mut out = a.data().to_vec();
    let rd = row.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |_, dst| {
        for orow in dst.chunks_exact_mut(n) {
            for j in 0..n {
                orow[j] *= rd[j];
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

pub fn gelu(a: &Tensor) -> Tensor {
    map(a, math::rep_gelu)
}

pub fn silu(a: &Tensor) -> Tensor {
    map(a, math::rep_silu)
}

pub fn tanh(a: &Tensor) -> Tensor {
    map(a, math::rep_tanh)
}

pub fn relu(a: &Tensor) -> Tensor {
    map(a, |x| if x > 0.0 { x } else { 0.0 })
}

pub fn exp(a: &Tensor) -> Tensor {
    map(a, math::rep_exp)
}

pub fn sigmoid(a: &Tensor) -> Tensor {
    map(a, math::rep_sigmoid)
}

// ---------------------------------------------------------------------------
// reductions — the order-critical operators
// ---------------------------------------------------------------------------

/// Fixed-order (ascending index) sum of a slice — THE canonical
/// order-sensitive reduction all RepOps reductions are built from.
#[inline]
pub fn sum_slice(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Sum over the last dim: `[..., n] -> [...]`.
///
/// Output rows are independent, so they fan out to the pool; *within* a
/// row the ascending-j accumulation of [`sum_slice`] is untouched.
pub fn sum_lastdim(a: &Tensor) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut data = vec![0.0f32; rows];
    let ad = a.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut data, 1, min_rows, |first, dst| {
        for (o, r) in dst.iter_mut().zip(first..) {
            *o = sum_slice(&ad[r * n..(r + 1) * n]);
        }
    });
    let mut shape = a.shape().to_vec();
    shape.pop();
    Tensor::new(shape, data)
}

/// Total sum of all elements (ascending flat index).
pub fn sum_all(a: &Tensor) -> f32 {
    sum_slice(a.data())
}

/// Column sums: `[r, n] -> [n]`, accumulating rows in ascending order.
/// (Used for bias gradients; row-ascending is the fixed order.)
///
/// The row dimension is order-critical here, so the split is over
/// *columns*: every column's accumulation still walks rows 0..r ascending
/// inside one chunk, and column subsets are independent outputs.
pub fn sum_axis0(a: &Tensor) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut out = vec![0.0f32; n];
    let ad = a.data();
    let min_cols = (parallel::EW_GRAIN / rows.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, 1, min_cols, |first, dst| {
        for r in 0..rows {
            let row = &ad[r * n + first..r * n + first + dst.len()];
            for (o, &x) in dst.iter_mut().zip(row) {
                *o += x;
            }
        }
    });
    Tensor::new([n], out)
}

/// Max over the last dim (ascending scan; ties keep the earlier value).
pub fn max_lastdim(a: &Tensor) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut data = vec![0.0f32; rows];
    let ad = a.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut data, 1, min_rows, |first, dst| {
        for (o, r) in dst.iter_mut().zip(first..) {
            let row = &ad[r * n..(r + 1) * n];
            let mut m = row[0];
            for &x in &row[1..] {
                if x > m {
                    m = x;
                }
            }
            *o = m;
        }
    });
    let mut shape = a.shape().to_vec();
    shape.pop();
    Tensor::new(shape, data)
}

/// Numerically-stable softmax over the last dim, all reductions fixed-order.
/// Rows are independent → pool; the per-row max scan and ascending-j sum
/// are unchanged inside each chunk.
pub fn softmax_lastdim(a: &Tensor) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut out = vec![0.0f32; rows * n];
    let ad = a.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |first, dst| {
        for (orow, r) in dst.chunks_exact_mut(n).zip(first..) {
            let row = &ad[r * n..(r + 1) * n];
            let mut m = row[0];
            for &x in &row[1..] {
                if x > m {
                    m = x;
                }
            }
            let mut s = 0.0f32;
            for (o, &x) in orow.iter_mut().zip(row) {
                let e = math::rep_exp(x - m);
                *o = e;
                s += e; // ascending j
            }
            let inv = 1.0 / s;
            for o in orow.iter_mut() {
                *o *= inv;
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// Log-softmax over the last dim (stable: `x - m - ln Σ e^{x-m}`).
pub fn log_softmax_lastdim(a: &Tensor) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    let mut out = vec![0.0f32; rows * n];
    let ad = a.data();
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |first, dst| {
        for (orow, r) in dst.chunks_exact_mut(n).zip(first..) {
            let row = &ad[r * n..(r + 1) * n];
            let mut m = row[0];
            for &x in &row[1..] {
                if x > m {
                    m = x;
                }
            }
            let mut s = 0.0f32;
            for &x in row {
                s += math::rep_exp(x - m);
            }
            let lse = math::rep_ln(s);
            for (o, &x) in orow.iter_mut().zip(row) {
                *o = (x - m) - lse;
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// LayerNorm over the last dim: `γ · (x-μ)/√(σ²+ε) + β`.
/// Mean and variance accumulate in ascending `j`; variance is the biased
/// (1/n) two-pass estimator, matching `torch.nn.LayerNorm` semantics.
pub fn layernorm(a: &Tensor, gamma: &Tensor, beta: &Tensor, eps: f32) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    assert_eq!(gamma.shape(), [n]);
    assert_eq!(beta.shape(), [n]);
    let mut out = vec![0.0f32; rows * n];
    let inv_n = 1.0 / n as f32;
    let (ad, gd, bd) = (a.data(), gamma.data(), beta.data());
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |first, dst| {
        for (orow, r) in dst.chunks_exact_mut(n).zip(first..) {
            let row = &ad[r * n..(r + 1) * n];
            let mean = sum_slice(row) * inv_n;
            let mut var = 0.0f32;
            for &x in row {
                let d = x - mean;
                var += d * d;
            }
            var *= inv_n;
            let inv_std = math::rep_rsqrt(var + eps);
            for j in 0..n {
                orow[j] = (row[j] - mean) * inv_std * gd[j] + bd[j];
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

/// RMSNorm over the last dim (the Llama normalization): `γ · x/√(μ(x²)+ε)`.
pub fn rmsnorm(a: &Tensor, gamma: &Tensor, eps: f32) -> Tensor {
    let (rows, n) = rows_lastdim(a);
    assert_eq!(gamma.shape(), [n]);
    let mut out = vec![0.0f32; rows * n];
    let inv_n = 1.0 / n as f32;
    let (ad, gd) = (a.data(), gamma.data());
    let min_rows = (parallel::EW_GRAIN / n.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, n, min_rows, |first, dst| {
        for (orow, r) in dst.chunks_exact_mut(n).zip(first..) {
            let row = &ad[r * n..(r + 1) * n];
            let mut ms = 0.0f32;
            for &x in row {
                ms += x * x;
            }
            let inv_rms = math::rep_rsqrt(ms * inv_n + eps);
            for j in 0..n {
                orow[j] = row[j] * inv_rms * gd[j];
            }
        }
    });
    Tensor::new(a.shape().to_vec(), out)
}

// ---------------------------------------------------------------------------
// gather / embedding
// ---------------------------------------------------------------------------

/// Embedding lookup: `table[v,d]` gathered by integer-valued `ids[...]`,
/// producing `[..., d]`. Pure data movement — each output row is one
/// independent copy, so id ranges fan out to the pool.
pub fn embedding(table: &Tensor, ids: &Tensor) -> Tensor {
    assert_eq!(table.rank(), 2);
    let (v, d) = (table.shape()[0], table.shape()[1]);
    let mut out = vec![0.0f32; ids.numel() * d];
    let (td, idd) = (table.data(), ids.data());
    let min_rows = (parallel::EW_GRAIN / d.max(1)).max(1);
    parallel::for_each_row_chunk(&mut out, d, min_rows, |first, dst| {
        for (orow, pos) in dst.chunks_exact_mut(d).zip(first..) {
            let idf = idd[pos];
            let idx = idf as usize;
            assert!(
                idf >= 0.0 && idf.fract() == 0.0 && idx < v,
                "embedding id {idf} out of range for table [{v},{d}]"
            );
            orow.copy_from_slice(&td[idx * d..(idx + 1) * d]);
        }
    });
    let mut shape = ids.shape().to_vec();
    shape.push(d);
    Tensor::new(shape, out)
}

/// Scatter-add gradient of [`embedding`]: accumulates `grad[..., d]` rows
/// into a zero `[v, d]` table in ascending occurrence order (the fixed order
/// that makes duplicate ids reproducible).
///
/// Deliberately serial: duplicate ids make the occurrence dimension
/// order-critical (two threads scatter-adding into the same table row
/// would race AND reassociate), and id→row is data-dependent so there is
/// no shape-only partition of the output. Stays a single ascending walk.
pub fn embedding_grad(v: usize, ids: &Tensor, grad: &Tensor) -> Tensor {
    let d = *grad.shape().last().unwrap();
    assert_eq!(grad.numel(), ids.numel() * d);
    let mut out = vec![0.0f32; v * d];
    for (pos, &idf) in ids.data().iter().enumerate() {
        let idx = idf as usize;
        let g = &grad.data()[pos * d..(pos + 1) * d];
        let dst = &mut out[idx * d..(idx + 1) * d];
        for j in 0..d {
            dst[j] += g[j];
        }
    }
    Tensor::new([v, d], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        // the paper's §3.2 pseudo-code: i-j-k with ascending k — must be
        // BITWISE identical to our vectorizable i-k-j formulation.
        let (m, k, n) = mm_dims(a, b);
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut sum = 0.0f32;
                for kk in 0..k {
                    sum += a.data()[i * k + kk] * b.data()[kk * n + j];
                }
                c[i * n + j] = sum;
            }
        }
        Tensor::new([m, n], c)
    }

    #[test]
    fn matmul_matches_paper_pseudocode_bitwise() {
        for (m, k, n, seed) in [(3, 5, 4, 1), (17, 33, 9, 2), (64, 128, 32, 3)] {
            let a = Tensor::rand([m, k], seed, 1.0);
            let b = Tensor::rand([k, n], seed + 100, 1.0);
            assert!(matmul(&a, &b).bit_eq(&naive_matmul(&a, &b)), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_remainder_shapes_match_pseudocode_bitwise() {
        // m, k, n deliberately not multiples of JB/KB: the remainder panel
        // and the panels-path dispatch must still match the naive bits.
        for (m, k, n, seed) in [(33, 300, 47, 4), (1, 257, 96, 5), (65, 31, 33, 6)] {
            let a = Tensor::rand([m, k], seed, 1.0);
            let b = Tensor::rand([k, n], seed + 100, 1.0);
            assert!(matmul(&a, &b).bit_eq(&naive_matmul(&a, &b)), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Tensor::rand([4, 4], 7, 1.0);
        let mut eye = Tensor::zeros([4, 4]);
        for i in 0..4 {
            eye.data_mut()[i * 4 + i] = 1.0;
        }
        assert!(matmul(&a, &eye).bit_eq(&a));
        assert!(matmul(&eye, &a).bit_eq(&a));
    }

    #[test]
    fn bmm_matches_per_batch_matmul() {
        let a = Tensor::rand([3, 4, 5], 11, 1.0);
        let b = Tensor::rand([3, 5, 6], 12, 1.0);
        let c = bmm(&a, &b);
        for ib in 0..3 {
            let a2 = Tensor::new([4, 5], a.data()[ib * 20..(ib + 1) * 20].to_vec());
            let b2 = Tensor::new([5, 6], b.data()[ib * 30..(ib + 1) * 30].to_vec());
            let want = matmul(&a2, &b2);
            let got = Tensor::new([4, 6], c.data()[ib * 24..(ib + 1) * 24].to_vec());
            assert!(got.bit_eq(&want));
        }
    }

    #[test]
    fn transpose_involution() {
        let a = Tensor::rand([5, 7], 3, 1.0);
        assert!(transpose2d(&transpose2d(&a)).bit_eq(&a));
        let b = Tensor::rand([2, 5, 7], 4, 1.0);
        assert!(transpose_last2(&transpose_last2(&b)).bit_eq(&b));
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let a = Tensor::rand([6, 33], 5, 8.0);
        let s = softmax_lastdim(&a);
        for r in 0..6 {
            let sum: f32 = s.data()[r * 33..(r + 1) * 33].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r} sums to {sum}");
        }
        assert!(s.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn softmax_shift_invariant() {
        let a = Tensor::rand([2, 16], 6, 3.0);
        let shifted = map(&a, |x| x + 100.0);
        // stable softmax subtracts the max, so a constant shift is nearly a
        // no-op (up to the rounding of x+100 itself).
        assert!(softmax_lastdim(&a).max_abs_diff(&softmax_lastdim(&shifted)) < 2e-6);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let a = Tensor::rand([4, 20], 8, 5.0);
        let ls = log_softmax_lastdim(&a);
        let s = softmax_lastdim(&a);
        for i in 0..a.numel() {
            assert!((ls.data()[i].exp() - s.data()[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_normalizes() {
        let a = Tensor::rand([4, 64], 9, 2.0);
        let g = Tensor::full([64], 1.0);
        let b = Tensor::zeros([64]);
        let o = layernorm(&a, &g, &b, 1e-5);
        for r in 0..4 {
            let row = &o.data()[r * 64..(r + 1) * 64];
            let mean: f32 = row.iter().sum::<f32>() / 64.0;
            let var: f32 = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-5, "row {r} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "row {r} var {var}");
        }
    }

    #[test]
    fn rmsnorm_unit_gamma_unit_rms() {
        let a = Tensor::rand([3, 32], 10, 2.0);
        let g = Tensor::full([32], 1.0);
        let o = rmsnorm(&a, &g, 1e-6);
        for r in 0..3 {
            let row = &o.data()[r * 32..(r + 1) * 32];
            let ms: f32 = row.iter().map(|x| x * x).sum::<f32>() / 32.0;
            assert!((ms - 1.0).abs() < 1e-3, "row {r} mean-square {ms}");
        }
    }

    #[test]
    fn sum_axis0_matches_transpose_sum() {
        let a = Tensor::rand([7, 5], 11, 1.0);
        let got = sum_axis0(&a);
        let t = transpose2d(&a);
        let want = sum_lastdim(&t);
        // same math, different order — only approximately equal in general,
        // but both are deterministic; check approx here.
        assert!(got.max_abs_diff(&want) < 1e-5);
    }

    #[test]
    fn embedding_roundtrip_and_grad() {
        let table = Tensor::rand([10, 4], 12, 1.0);
        let ids = Tensor::new([3], vec![2.0, 7.0, 2.0]);
        let e = embedding(&table, &ids);
        assert_eq!(e.shape(), &[3, 4]);
        assert_eq!(&e.data()[0..4], &table.data()[8..12]);
        assert_eq!(&e.data()[4..8], &table.data()[28..32]);
        // duplicate id 2 accumulates both rows
        let grad = Tensor::full([3, 4], 1.0);
        let g = embedding_grad(10, &ids, &grad);
        assert_eq!(g.data()[2 * 4], 2.0);
        assert_eq!(g.data()[7 * 4], 1.0);
        assert_eq!(g.data()[0], 0.0);
    }

    #[test]
    #[should_panic]
    fn embedding_rejects_out_of_range() {
        let table = Tensor::rand([4, 2], 1, 1.0);
        let ids = Tensor::new([1], vec![4.0]);
        embedding(&table, &ids);
    }

    #[test]
    fn elementwise_shapes_checked() {
        let a = Tensor::rand([2, 3], 1, 1.0);
        let b = Tensor::rand([2, 3], 2, 1.0);
        assert_eq!(add(&a, &b).shape(), &[2, 3]);
        let s = sub(&add(&a, &b), &b);
        // (a+b)-b is NOT bitwise a in FP; only approx
        assert!(s.max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn max_lastdim_picks_max() {
        let a = Tensor::new([2, 3], vec![1.0, 5.0, 3.0, -2.0, -7.0, -1.0]);
        let m = max_lastdim(&a);
        assert_eq!(m.data(), &[5.0, -1.0]);
    }
}
