//! Canonical stats snapshot and its two renderings (JSON, Prometheus
//! text).
//!
//! [`Snapshot`] is the single source of truth the whole stats plane moves
//! around: [`Registry::snapshot`](super::Registry::snapshot) produces it,
//! `Response::Stats` carries it over the wire, `verde stats` renders it.
//! Key names are part of the **versioned public surface** — see the
//! metric catalog in `rust/README.md`; [`STATS_VERSION`](super::STATS_VERSION)
//! bumps whenever a key is renamed or its meaning changes (adding keys is
//! backward compatible).

use std::fmt::Write as _;

/// Snapshot of one histogram: `buckets.len() == bounds.len() + 1` (the
/// final bucket counts observations above the last bound).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub bounds: Vec<u64>,
    pub buckets: Vec<u64>,
    pub sum: u64,
    pub count: u64,
}

/// Point-in-time view of a registry: sorted `(name, value)` pairs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Snapshot {
    /// Schema version of the key set ([`super::STATS_VERSION`]).
    pub version: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub histograms: Vec<(String, HistogramSnapshot)>,
}

impl Snapshot {
    /// The snapshot of a registry nothing has touched: current version,
    /// no instruments. Renders as zeros/empty sections, never NaN —
    /// mirroring the empty-`ServiceReport` guards.
    pub fn empty() -> Snapshot {
        Snapshot {
            version: super::STATS_VERSION,
            counters: Vec::new(),
            gauges: Vec::new(),
            histograms: Vec::new(),
        }
    }

    /// Value of a counter, `0` when absent (absent and never-incremented
    /// are indistinguishable by design).
    pub fn counter(&self, name: &str) -> u64 {
        lookup(&self.counters, name).unwrap_or(0)
    }

    /// Value of a gauge, `0` when absent.
    pub fn gauge(&self, name: &str) -> u64 {
        lookup(&self.gauges, name).unwrap_or(0)
    }

    /// A histogram by name.
    pub fn histogram(&self, name: &str) -> Option<&HistogramSnapshot> {
        self.histograms.iter().find(|(k, _)| k == name).map(|(_, h)| h)
    }

    /// Stable JSON rendering (sorted keys inherited from the registry's
    /// BTreeMaps):
    /// `{"stats_version":1,"counters":{..},"gauges":{..},"histograms":{..}}`.
    pub fn to_json(&self) -> String {
        let mut s = format!("{{\"stats_version\":{}", self.version);
        s.push_str(",\"counters\":{");
        for (i, (k, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{k}\":{v}");
        }
        s.push_str("},\"gauges\":{");
        for (i, (k, v)) in self.gauges.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{k}\":{v}");
        }
        s.push_str("},\"histograms\":{");
        for (i, (k, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "\"{k}\":{{\"bounds\":{:?},\"buckets\":{:?},\"sum\":{},\"count\":{}}}",
                h.bounds, h.buckets, h.sum, h.count);
        }
        s.push_str("}}");
        s
    }

    /// Prometheus text exposition: counters as `TYPE counter`, gauges as
    /// `TYPE gauge`, histograms as cumulative `_bucket{le=..}` series plus
    /// `_sum` / `_count`.
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(s, "# TYPE {k} counter\n{k} {v}");
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(s, "# TYPE {k} gauge\n{k} {v}");
        }
        for (k, h) in &self.histograms {
            let _ = writeln!(s, "# TYPE {k} histogram");
            let mut cum = 0u64;
            for (i, b) in h.bounds.iter().enumerate() {
                cum += h.buckets[i];
                let _ = writeln!(s, "{k}_bucket{{le=\"{b}\"}} {cum}");
            }
            cum += h.buckets.last().copied().unwrap_or(0);
            let _ = writeln!(s, "{k}_bucket{{le=\"+Inf\"}} {cum}");
            let _ = writeln!(s, "{k}_sum {}\n{k}_count {}", h.sum, h.count);
        }
        s
    }
}

fn lookup(pairs: &[(String, u64)], name: &str) -> Option<u64> {
    pairs.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Registry;

    #[test]
    fn empty_snapshot_renders_zeros_not_nan() {
        let s = Snapshot::empty();
        assert_eq!(s.counter("anything"), 0);
        assert_eq!(s.gauge("anything"), 0);
        assert_eq!(
            s.to_json(),
            format!(
                "{{\"stats_version\":{},\"counters\":{{}},\"gauges\":{{}},\"histograms\":{{}}}}",
                crate::obs::STATS_VERSION
            )
        );
        assert_eq!(s.to_prometheus(), "");
    }

    #[test]
    fn json_rendering_is_stable_and_sorted() {
        let reg = Registry::new();
        reg.counter("zz").add(3);
        reg.counter("aa").add(1);
        reg.gauge("depth").set(2);
        let json = reg.snapshot().to_json();
        assert!(json.starts_with(&format!("{{\"stats_version\":{}", crate::obs::STATS_VERSION)));
        let aa = json.find("\"aa\":1").expect("aa rendered");
        let zz = json.find("\"zz\":3").expect("zz rendered");
        assert!(aa < zz, "keys sorted: {json}");
        assert!(json.contains("\"gauges\":{\"depth\":2}"));
    }

    #[test]
    fn prometheus_buckets_are_cumulative_and_end_at_inf() {
        let reg = Registry::new();
        let h = reg.histogram("lat_us", &[10, 100]);
        h.observe(5);
        h.observe(50);
        h.observe(5_000);
        let text = reg.snapshot().to_prometheus();
        assert!(text.contains("# TYPE lat_us histogram"));
        assert!(text.contains("lat_us_bucket{le=\"10\"} 1"));
        assert!(text.contains("lat_us_bucket{le=\"100\"} 2"));
        assert!(text.contains("lat_us_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("lat_us_sum 5055"));
        assert!(text.contains("lat_us_count 3"));
    }

    #[test]
    fn snapshot_accessors_find_instruments() {
        let reg = Registry::new();
        reg.counter("c").add(9);
        reg.histogram("h", &[1]).observe(2);
        let s = reg.snapshot();
        assert_eq!(s.counter("c"), 9);
        assert_eq!(s.counter("missing"), 0);
        let h = s.histogram("h").expect("histogram present");
        assert_eq!(h.buckets, vec![0, 1]);
    }
}
