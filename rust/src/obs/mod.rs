//! Observability: a dependency-free metrics registry, a per-job span
//! tracer, and renderers for a live stats plane.
//!
//! Three pieces, one flow:
//!
//! * [`Registry`] — named atomic **counters**, **gauges**, and
//!   fixed-bucket **histograms**. Handles are registered once and cached;
//!   recording is lock-free relaxed atomics, cheap enough for the
//!   coordinator tick, the mux drive loop, and the training step.
//! * [`SpanLog`] (one per registry) — per-job lifecycle **span events**
//!   (submit → queue → lease → dispatch → fetch/verify/seed → verdict →
//!   settle) on a monotonic clock, gated off by default behind one atomic
//!   load.
//! * [`Snapshot`] — the canonical point-in-time view. It is what
//!   `Response::Stats` carries over the wire, what `verde stats` prints,
//!   and what the JSON/Prometheus renderers consume.
//!
//! Two registry tiers exist on purpose:
//!
//! * **Per-delegation** — `service::Delegation` owns a private registry
//!   (`coord_*` keys) whose totals reconcile *exactly* with its
//!   `ServiceReport`; tests assert equality.
//! * **Process-global** ([`global`]) — cross-cutting layers with no
//!   single owner (mux driver, TCP framing, disputes, trainer, RepOps
//!   kernels) accumulate monotonic totals here. Parallel tests share this
//!   registry, so its values are monotonic evidence, not exact
//!   per-run accounting.
//!
//! The key catalog is documented in `rust/README.md` and versioned by
//! [`STATS_VERSION`].

pub mod registry;
pub mod render;
pub mod span;

pub use registry::{Counter, Gauge, Histogram, Registry, COUNT_BOUNDS, LATENCY_US_BOUNDS};
pub use render::{HistogramSnapshot, Snapshot};
pub use span::{SpanEvent, SpanLog, Stage};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

/// Version of the stats key set carried in every [`Snapshot`]. Bump on
/// rename or semantic change of an existing key; additions don't bump.
pub const STATS_VERSION: u64 = 1;

/// The process-global registry for cross-cutting layers. Created on first
/// use; never reset (its counters are process-lifetime monotonic totals).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

static KERNEL_TIMING: AtomicBool = AtomicBool::new(false);

/// Opt into per-kernel timing (`repops_*` histograms fed from
/// `tensor::profile::KernelTimer`). Off by default: kernel dispatch is
/// the innermost hot loop, and two `Instant::now()` calls per operator
/// are only worth paying when someone is looking.
pub fn enable_kernel_timing() {
    KERNEL_TIMING.store(true, Ordering::Relaxed);
}

/// Is per-kernel timing on? One relaxed load; kernels check this first.
pub fn kernel_timing_enabled() -> bool {
    KERNEL_TIMING.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_is_one_shared_instance() {
        global().counter("obs_selftest").add(2);
        assert!(global().counter("obs_selftest").get() >= 2, "other tests may also bump it");
    }

    #[test]
    fn kernel_timing_defaults_off_until_enabled() {
        // Note: other tests in this binary may enable it first; only the
        // transition to `true` is asserted.
        enable_kernel_timing();
        assert!(kernel_timing_enabled());
    }
}
