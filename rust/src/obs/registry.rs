//! The metrics registry: named atomic counters, gauges, and fixed-bucket
//! histograms behind one cheaply clonable handle.
//!
//! The design splits registration from recording. Registration
//! ([`Registry::counter`] / [`Registry::gauge`] / [`Registry::histogram`])
//! takes a short-lived `Mutex` over a name → cell map and hands back a
//! handle wrapping the `Arc<AtomicU64>` (or histogram core) directly. Hot
//! paths cache the handle once and then record with plain relaxed atomic
//! ops — no lock, no allocation, no map lookup per event. That is what
//! keeps instrumentation inside the coordinator tick, the mux drive loop,
//! and the training step affordable.
//!
//! [`Registry::snapshot`] materializes a point-in-time [`Snapshot`] of
//! every registered instrument (zero-valued instruments included, so an
//! idle service renders zeros rather than an empty document — the same
//! guard `ServiceReport::to_json` gives an empty report).
//!
//! Key families registered against a delegation's registry: `coord_*`
//! (event-loop counters/gauges, including the optimistic-tier
//! `coord_audit_{sampled,passed,escalated,steps}` and
//! `coord_stake_{slashed,locked}` instruments — see the
//! [`service`](crate::service) module docs for the full catalog) and
//! `worker_*` (per-[`WorkerHost`](crate::service::worker::WorkerHost)
//! registries). Counters fold from the same settling segment outcomes the
//! service report aggregates, so snapshot totals reconcile exactly with
//! [`ServiceReport`](crate::service::coordinator::ServiceReport).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::render::{HistogramSnapshot, Snapshot};
use super::span::SpanLog;

/// A monotonically increasing counter. Cloning shares the cell.
#[derive(Clone)]
pub struct Counter {
    cell: Arc<AtomicU64>,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, delta: u64) {
        self.cell.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }
}

/// A last-write-wins gauge (queue depths, pool occupancy). Values are
/// non-negative by construction — every instrumented quantity is a count.
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Gauge {
    pub fn set(&self, value: u64) {
        self.cell.store(value, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.cell.load(Ordering::Relaxed)
    }

    /// Relaxed increment, for gauges tracking live occupancy from many
    /// threads (e.g. `net_tcp_conns`).
    pub fn add(&self, delta: u64) {
        self.cell.fetch_add(delta, Ordering::Relaxed);
    }

    /// Saturating decrement: an unbalanced `sub` clamps at zero instead
    /// of wrapping.
    pub fn sub(&self, delta: u64) {
        let _ = self
            .cell
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
                Some(v.saturating_sub(delta))
            });
    }
}

/// Shared storage of one histogram: `bounds.len() + 1` buckets (the last
/// is the overflow bucket), plus sum and count for mean recovery.
pub(crate) struct HistogramCore {
    pub(crate) bounds: Vec<u64>,
    pub(crate) buckets: Vec<AtomicU64>,
    pub(crate) sum: AtomicU64,
    pub(crate) count: AtomicU64,
}

impl HistogramCore {
    fn new(bounds: &[u64]) -> HistogramCore {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        HistogramCore {
            bounds: bounds.to_vec(),
            buckets: (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn observe(&self, value: u64) {
        // Linear scan: instrument bucket counts are small (≤ ~12) and the
        // scan is branch-predictable, beating a binary search at this size.
        let idx = self
            .bounds
            .iter()
            .position(|&b| value <= b)
            .unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(value, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            bounds: self.bounds.clone(),
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum: self.sum.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }
}

/// A fixed-bucket histogram handle. Cloning shares the core.
#[derive(Clone)]
pub struct Histogram {
    core: Arc<HistogramCore>,
}

impl Histogram {
    pub fn observe(&self, value: u64) {
        self.core.observe(value);
    }

    /// Record a duration in whole microseconds (the unit every `*_us`
    /// histogram in the catalog uses).
    pub fn observe_micros(&self, d: std::time::Duration) {
        self.observe(d.as_micros().min(u128::from(u64::MAX)) as u64);
    }

    pub fn count(&self) -> u64 {
        self.core.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.core.sum.load(Ordering::Relaxed)
    }
}

/// Default bucket bounds for microsecond-latency histograms: 10 µs … 10 s
/// in half-decade steps.
pub const LATENCY_US_BOUNDS: [u64; 12] = [
    10, 50, 100, 500, 1_000, 5_000, 10_000, 50_000, 100_000, 500_000, 1_000_000, 10_000_000,
];

/// Default bucket bounds for small-count histograms (events per tick).
pub const COUNT_BOUNDS: [u64; 8] = [0, 1, 2, 4, 8, 16, 64, 256];

struct Inner {
    epoch: Instant,
    counters: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    gauges: Mutex<BTreeMap<String, Arc<AtomicU64>>>,
    histograms: Mutex<BTreeMap<String, Arc<HistogramCore>>>,
    spans: SpanLog,
}

/// The registry handle. Cloning is an `Arc` bump; every clone addresses
/// the same instruments and span log.
#[derive(Clone)]
pub struct Registry {
    inner: Arc<Inner>,
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        let epoch = Instant::now();
        Registry {
            inner: Arc::new(Inner {
                epoch,
                counters: Mutex::new(BTreeMap::new()),
                gauges: Mutex::new(BTreeMap::new()),
                histograms: Mutex::new(BTreeMap::new()),
                spans: SpanLog::new(epoch),
            }),
        }
    }

    /// The instant all span timestamps are measured from.
    pub fn epoch(&self) -> Instant {
        self.inner.epoch
    }

    /// Register (or look up) a counter. Call once and cache the handle;
    /// recording through the handle is lock-free.
    pub fn counter(&self, name: &str) -> Counter {
        let mut map = self.inner.counters.lock().unwrap();
        let cell = map.entry(name.to_string()).or_default();
        Counter { cell: Arc::clone(cell) }
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut map = self.inner.gauges.lock().unwrap();
        let cell = map.entry(name.to_string()).or_default();
        Gauge { cell: Arc::clone(cell) }
    }

    /// Register (or look up) a histogram with the given ascending bucket
    /// bounds. The first registration fixes the bounds; later callers get
    /// the existing core regardless of the bounds they pass.
    pub fn histogram(&self, name: &str, bounds: &[u64]) -> Histogram {
        let mut map = self.inner.histograms.lock().unwrap();
        let core = map
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(HistogramCore::new(bounds)));
        Histogram { core: Arc::clone(core) }
    }

    /// The per-job lifecycle span log attached to this registry.
    pub fn spans(&self) -> &SpanLog {
        &self.inner.spans
    }

    /// Point-in-time snapshot of every registered instrument, keys sorted.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .inner
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let gauges = self
            .inner
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.load(Ordering::Relaxed)))
            .collect();
        let histograms = self
            .inner
            .histograms
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot()))
            .collect();
        Snapshot { version: super::STATS_VERSION, counters, gauges, histograms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_handles_share_one_cell() {
        let reg = Registry::new();
        let a = reg.counter("hits");
        let b = reg.counter("hits");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5);
        assert_eq!(reg.counter("hits").get(), 5);
        assert_eq!(reg.counter("other").get(), 0, "registration alone reads zero");
    }

    #[test]
    fn concurrent_increments_do_not_lose_updates() {
        let reg = Registry::new();
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let c = reg.counter("races");
                let h = reg.histogram("lat", &LATENCY_US_BOUNDS);
                std::thread::spawn(move || {
                    for i in 0..1000 {
                        c.inc();
                        h.observe(i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(reg.counter("races").get(), 8_000);
        let snap = reg.snapshot();
        let hist = &snap.histograms[0].1;
        assert_eq!(hist.count, 8_000);
        assert_eq!(hist.buckets.iter().sum::<u64>(), 8_000, "every observation lands in a bucket");
    }

    #[test]
    fn histogram_bucket_edges_are_inclusive_upper_bounds() {
        let reg = Registry::new();
        let h = reg.histogram("edges", &[10, 100]);
        h.observe(0); // ≤ 10
        h.observe(10); // ≤ 10 (edge is inclusive, Prometheus `le` semantics)
        h.observe(11); // ≤ 100
        h.observe(100); // ≤ 100
        h.observe(101); // overflow
        h.observe(u64::MAX); // overflow
        let snap = reg.snapshot();
        let hist = &snap.histograms[0].1;
        assert_eq!(hist.bounds, vec![10, 100]);
        assert_eq!(hist.buckets, vec![2, 2, 2]);
        assert_eq!(hist.count, 6);
        assert_eq!(h.count(), 6);
    }

    #[test]
    fn gauge_is_last_write_wins() {
        let reg = Registry::new();
        let g = reg.gauge("depth");
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
        assert_eq!(reg.snapshot().gauges, vec![("depth".to_string(), 3)]);
    }

    #[test]
    fn snapshot_is_sorted_and_includes_zero_instruments() {
        let reg = Registry::new();
        reg.counter("z_last");
        reg.counter("a_first").inc();
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["a_first", "z_last"]);
        assert_eq!(snap.counters[1].1, 0, "registered-but-untouched renders as zero");
    }
}
