//! Per-job lifecycle tracing: structured span events on a monotonic clock.
//!
//! A [`SpanLog`] records the coordinator-side timeline of every job —
//! submit → queue → lease → dispatch → verdict → checkpoint
//! fetch/verify/seed → settle — as [`SpanEvent`]s stamped with the
//! duration since the owning registry's epoch plus job/segment/worker
//! identity. Tracing is **off by default**: [`SpanLog::trace`] is a single
//! relaxed atomic load when disabled, so instrumented hot paths cost
//! nothing measurable until a caller opts in with [`SpanLog::enable`]
//! (tests, the latency bench, `verde coordinator --trace`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// One stage of the job lifecycle. Stages are ordered roughly as a
/// segment experiences them; `Settle` with `seg: None` closes the job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Job accepted by the coordinator (one per job).
    Submit,
    /// Segment pushed onto the lease queue (initial placement *and* every
    /// requeue — queue events per job ≥ segments per job).
    Queue,
    /// A worker group leased for a segment (one event per dispatch).
    Lease,
    /// Segment handed to one of its leased workers (k events per
    /// dispatch, each carrying the worker's name).
    Dispatch,
    /// Verified checkpoint chunks fetched from a segment winner.
    Fetch,
    /// Fetched state Merkle-verified against the accepted commitment.
    Verify,
    /// Segment dispatched with a verified predecessor state (transfer
    /// pipeline), not trained from genesis.
    Seed,
    /// Optimistic tier: a sampled replay audit leased against the named
    /// committer (the event's worker is the *accused*, not the auditor).
    Audit,
    /// Segment verdict reached: a commitment was accepted.
    Verdict,
    /// Segment recorded (`seg: Some`) or whole job finished (`seg: None`).
    Settle,
}

impl Stage {
    /// Stable lowercase label used by renderers and the bench.
    pub fn label(self) -> &'static str {
        match self {
            Stage::Submit => "submit",
            Stage::Queue => "queue",
            Stage::Lease => "lease",
            Stage::Dispatch => "dispatch",
            Stage::Fetch => "fetch",
            Stage::Verify => "verify",
            Stage::Seed => "seed",
            Stage::Audit => "audit",
            Stage::Verdict => "verdict",
            Stage::Settle => "settle",
        }
    }
}

/// One recorded lifecycle event.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// Monotonic time since the registry epoch.
    pub at: Duration,
    pub job_id: u64,
    /// Segment index within the job; `None` for job-level events.
    pub seg: Option<u64>,
    pub stage: Stage,
    /// Worker name, where one worker is the subject (lease, fetch).
    pub worker: Option<String>,
}

/// An append-only, gated event log. All methods take `&self`; the log is
/// shared by clone of the owning [`Registry`](super::Registry).
pub struct SpanLog {
    enabled: AtomicBool,
    epoch: Instant,
    events: Mutex<Vec<SpanEvent>>,
}

impl SpanLog {
    pub(crate) fn new(epoch: Instant) -> SpanLog {
        SpanLog { enabled: AtomicBool::new(false), epoch, events: Mutex::new(Vec::new()) }
    }

    /// Turn tracing on (idempotent). Events recorded before enabling are
    /// simply absent — there is no replay.
    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one event. A no-op (one relaxed load) while disabled.
    pub fn trace(&self, job_id: u64, seg: Option<u64>, stage: Stage, worker: Option<&str>) {
        if !self.enabled.load(Ordering::Relaxed) {
            return;
        }
        let at = self.epoch.elapsed();
        let mut events = self.events.lock().unwrap();
        events.push(SpanEvent { at, job_id, seg, stage, worker: map(worker) });
    }

    /// Copy of the full event log, in record order.
    pub fn events(&self) -> Vec<SpanEvent> {
        self.events.lock().unwrap().clone()
    }

    /// Number of recorded events matching `stage`.
    pub fn count(&self, stage: Stage) -> usize {
        self.events.lock().unwrap().iter().filter(|e| e.stage == stage).count()
    }

    /// Per-job submit→settle latency: for every job with both a `Submit`
    /// and a job-level `Settle` (`seg: None`), the elapsed duration
    /// between them, in job-settle order. The latency bench feeds its
    /// percentile table from this.
    pub fn job_latencies(&self) -> Vec<Duration> {
        let events = self.events.lock().unwrap();
        let mut out = Vec::new();
        for e in events.iter() {
            if e.stage == Stage::Settle && e.seg.is_none() {
                let submit = events
                    .iter()
                    .find(|s| s.stage == Stage::Submit && s.job_id == e.job_id)
                    .map(|s| s.at);
                if let Some(t0) = submit {
                    out.push(e.at.saturating_sub(t0));
                }
            }
        }
        out
    }
}

fn map(worker: Option<&str>) -> Option<String> {
    worker.map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log() -> SpanLog {
        SpanLog::new(Instant::now())
    }

    #[test]
    fn disabled_log_records_nothing() {
        let l = log();
        l.trace(1, None, Stage::Submit, None);
        assert!(!l.enabled());
        assert!(l.events().is_empty());
    }

    #[test]
    fn events_carry_identity_and_monotonic_time() {
        let l = log();
        l.enable();
        l.trace(7, None, Stage::Submit, None);
        l.trace(7, Some(0), Stage::Queue, None);
        l.trace(7, Some(0), Stage::Lease, Some("w0"));
        let ev = l.events();
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0].job_id, 7);
        assert_eq!(ev[2].worker.as_deref(), Some("w0"));
        assert!(ev[0].at <= ev[1].at && ev[1].at <= ev[2].at);
        assert_eq!(l.count(Stage::Queue), 1);
    }

    #[test]
    fn job_latency_pairs_submit_with_job_level_settle() {
        let l = log();
        l.enable();
        l.trace(1, None, Stage::Submit, None);
        l.trace(1, Some(0), Stage::Settle, None); // segment settle: not a job end
        assert!(l.job_latencies().is_empty());
        l.trace(1, None, Stage::Settle, None);
        l.trace(2, None, Stage::Settle, None); // settle without submit: skipped
        assert_eq!(l.job_latencies().len(), 1);
    }
}
