//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the Rust hot path.
//!
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in proto form; the text parser
//! reassigns ids — see /opt/xla-example/README.md).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;

/// A PJRT CPU client plus the artifact directory.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
}

/// One compiled executable.
pub struct Artifact {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

/// The AOT model manifest (`manifest.txt`): flat parameter order + config.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub params: Vec<(String, Vec<usize>)>,
    pub config: std::collections::BTreeMap<String, u64>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut m = Manifest::default();
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("config") => {
                    let key = it.next().ok_or_else(|| anyhow!("bad config line"))?;
                    let val: u64 = it.next().ok_or_else(|| anyhow!("bad config line"))?.parse()?;
                    m.config.insert(key.to_string(), val);
                }
                Some("param") => {
                    let name = it.next().ok_or_else(|| anyhow!("bad param line"))?;
                    let dims: Vec<usize> =
                        it.map(|d| d.parse()).collect::<Result<_, _>>()?;
                    m.params.push((name.to_string(), dims));
                }
                _ => {}
            }
        }
        if m.params.is_empty() {
            bail!("manifest {} has no params", path.display());
        }
        Ok(m)
    }

    pub fn cfg(&self, key: &str) -> u64 {
        self.config[key]
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }
}

/// FP32 tensor → PJRT literal.
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
}

/// Integer-valued FP32 tensor → i32 PJRT literal (token ids).
pub fn to_literal_i32(t: &Tensor) -> Result<xla::Literal> {
    let ints: Vec<i32> = t.data().iter().map(|&v| v as i32).collect();
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(&ints).reshape(&dims)?)
}

/// PJRT literal → FP32 tensor.
pub fn from_literal(l: &xla::Literal) -> Result<Tensor> {
    let shape = l.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

impl Runtime {
    /// Create a CPU PJRT client rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, dir: artifacts_dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> Result<Manifest> {
        Manifest::load(&self.dir.join("manifest.txt"))
    }

    /// Load + compile one HLO-text artifact by file name.
    pub fn load(&self, file: &str) -> Result<Artifact> {
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Artifact { exe, name: file.to_string() })
    }
}

impl Artifact {
    /// Execute with the given literals; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let first = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("{}: empty result", self.name))?;
        let lit = first.to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        Ok(lit.to_tuple()?)
    }

    /// Execute on FP32 tensors only (kernel artifacts).
    pub fn run_f32(&self, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|t| to_literal(t)).collect::<Result<_>>()?;
        let outs = self.run(&lits)?;
        outs.iter().map(from_literal).collect()
    }
}

/// Default artifact directory (`artifacts/` next to the binary's CWD, or
/// `$VERDE_ARTIFACTS`).
pub fn default_dir() -> PathBuf {
    std::env::var_os("VERDE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True when the AOT artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_present() -> bool {
    default_dir().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::rand([3, 5], 1, 2.0);
        let l = to_literal(&t).unwrap();
        let back = from_literal(&l).unwrap();
        assert!(t.bit_eq(&back));
    }

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("verde-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("manifest.txt");
        std::fs::write(&p, "config vocab 256\nconfig seq 16\nparam embed.w 256 64\nparam lm_head.w 64 256\n").unwrap();
        let m = Manifest::load(&p).unwrap();
        assert_eq!(m.cfg("vocab"), 256);
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.params[0], ("embed.w".to_string(), vec![256, 64]));
    }
}
