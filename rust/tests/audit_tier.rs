//! Acceptance battery for the staked spot-check audit tier: an all-honest
//! optimistic fleet settles a segmented job for strictly fewer worker-steps
//! than the k=2 replicated equivalent; a cheating optimistic worker is
//! caught by a sampled replay, convicted by the escalation tournament,
//! slashed in the stake ledger — and the job still returns the honest
//! verdict, in-process AND over real TCP; and a replay that can never run
//! (no independent auditor exists) degrades to replication instead of
//! wedging the job.

use std::net::TcpListener;

use verde::hash::Hash;
use verde::model::Preset;
use verde::net::tcp::{spawn_server, TcpEndpoint};
use verde::net::Endpoint;
use verde::service::{
    AuditSampler, Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig, WorkerHost,
    WorkerPool,
};
use verde::train::JobSpec;
use verde::verde::protocol::Request;
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

fn honest(spec: JobSpec) -> Hash {
    TrainerNode::honest("ref", spec).train()
}

/// THE acceptance criterion, honest half: an optimistic job over an
/// all-honest fleet settles every segment with the exact honest verdict
/// for `steps + Σ sampled-segment lengths` worker-steps — strictly less
/// than the `k × steps` a k=2 replicated run of the same job pays.
#[test]
fn honest_optimistic_fleet_undercuts_replication() {
    let plans =
        [("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest), ("w2", FaultPlan::Honest)];
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);

    // Replicated baseline: k=2 with state transfer costs exactly k × steps.
    let pool = in_process_pool(&plans);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(4).with_state_transfer())
        .wait();
    assert_eq!(outcome.accepted, Some(full));
    let replicated_steps = delegation.finish().total_steps_trained();
    assert_eq!(replicated_steps, 2 * 12);

    // Optimistic: one pinned staked worker, audit_rate 0.5. The sampler is
    // deterministic — with the default audit_seed (0) job 0 samples
    // segments 1 and 3 of 4 at rate 0.5 — so the cost is exact, not
    // statistical: 12 committer steps + 3 + 3 replayed.
    let sampler = AuditSampler::new(0);
    let sampled: Vec<usize> = (0..4).filter(|&g| sampler.sample(0, g as u64, 0.5)).collect();
    assert_eq!(sampled, vec![1, 3], "sampling schedule drifted");

    let pool = in_process_pool(&plans);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_segments(4).with_audit(0.5)).wait();

    assert!(!outcome.cancelled);
    assert_eq!(outcome.accepted, Some(full), "optimistic == replicated verdict: {outcome:?}");
    assert_eq!(outcome.winner.as_deref(), Some("w0"), "the job was pinned to one worker");
    assert_eq!(outcome.disputes, 0);
    assert_eq!(outcome.eliminated, 0);
    assert_eq!(outcome.segments.len(), 4);
    for (i, s) in outcome.segments.iter().enumerate() {
        assert_eq!(s.accepted, Some(honest(spec.prefix(s.end))), "segment {i}");
        assert_eq!(s.workers, vec!["w0".to_string()], "segment {i}: single-worker lease");
        assert_eq!(s.steps_trained, s.end - s.start, "segment {i} was pipeline-seeded");
        assert_eq!(s.audit_sampled, sampled.contains(&i), "segment {i}");
        assert_eq!(s.audit_passed, sampled.contains(&i), "honest replays match: segment {i}");
        assert!(!s.audit_escalated, "segment {i}");
        assert_eq!(s.audit_steps, if sampled.contains(&i) { s.end - s.start } else { 0 });
        assert_eq!(s.slashed, 0);
    }

    let report = delegation.finish();
    assert_eq!(report.total_audit_sampled(), 2);
    assert_eq!(report.total_audit_passed(), 2);
    assert_eq!(report.total_audit_escalated(), 0);
    assert_eq!(report.total_steps_trained(), 12, "the committer trains each delta once");
    assert_eq!(report.total_audit_steps(), 6, "replays re-train only sampled segments");
    let optimistic_steps = report.total_steps_trained() + report.total_audit_steps();
    assert!(
        optimistic_steps < replicated_steps,
        "audit tier must undercut replication: {optimistic_steps} vs {replicated_steps}"
    );
    // Stake: enrolled, nothing locked or slashed after the run.
    assert_eq!(report.stakes.len(), 1);
    assert_eq!(report.stakes[0].worker, "w0");
    assert_eq!(report.stakes[0].deposited, 1000);
    assert_eq!(report.stakes[0].locked, 0);
    assert_eq!(report.stakes[0].slashed, 0);
    assert_eq!(report.total_slashed(), 0);
    let json = report.to_json();
    assert!(json.contains("\"audit_sampled\":2"), "{json}");
    assert!(json.contains("\"audit_passed\":2"), "{json}");
    assert!(json.contains("\"stake_slashed\":0"), "{json}");
    assert_eq!(pool.idle(), 3, "all leases returned");
}

/// THE acceptance criterion, adversarial half: the pinned optimistic
/// worker tampers mid-job. Its per-segment commitment binds the cheat, the
/// sampled replay diverges, the escalation tournament convicts it, its
/// stake is slashed — and the job settles with the honest verdict.
#[test]
fn cheating_committer_is_convicted_and_slashed() {
    // The cheater sits at the front of the free list, so the optimistic
    // job pins to it. It tampers at step 5: segment 0 (steps 1..=3) is
    // honest, segment 1 (4..=6) carries the cheat.
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Tamper { step: Some(5), delta: 0.05 }),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_segments(4).with_audit(1.0)).wait();

    assert_eq!(outcome.accepted, Some(full), "honest verdict despite the cheat: {outcome:?}");
    assert!(outcome.eliminated >= 1, "the tournament eliminated the cheater");
    assert!(outcome.disputes >= 1, "escalation ran a real dispute");

    // Segment 0: honest commitment, replay matched.
    let s0 = &outcome.segments[0];
    assert!(s0.audit_sampled && s0.audit_passed && !s0.audit_escalated, "{s0:?}");
    assert_eq!(s0.slashed, 0);
    // Segment 1: divergent replay, escalated, convicted, slashed.
    let s1 = &outcome.segments[1];
    assert!(s1.audit_sampled && !s1.audit_passed && s1.audit_escalated, "{s1:?}");
    assert_eq!(s1.accepted, Some(honest(spec.prefix(6))), "tournament certified honesty");
    assert_eq!(s1.slashed, 1000, "the full deposit was confiscated");
    assert!(s1.audit_steps > 0, "the sunk optimistic attempt is on the bill");
    // Segments 2..: the job fell back to k-replication (no more audits).
    for s in &outcome.segments[2..] {
        assert!(!s.audit_sampled, "escalation turns the optimistic tier off: {s:?}");
        assert_eq!(s.workers.len(), 2, "k-replicated from here on");
        assert_eq!(s.accepted, Some(honest(spec.prefix(s.end))));
    }

    let report = delegation.finish();
    assert_eq!(report.total_audit_sampled(), 2);
    assert_eq!(report.total_audit_passed(), 1);
    assert_eq!(report.total_audit_escalated(), 1);
    assert_eq!(report.total_slashed(), 1000);
    let w0 = report.stakes.iter().find(|s| s.worker == "w0").expect("enrolled");
    assert_eq!(w0.slashed, 1000);
    assert_eq!(w0.locked, 0);
    assert_eq!(w0.available(), 0, "nothing left to stake");
    assert_eq!(pool.idle(), 3, "eliminations are not revocations; leases returned");
}

/// The same conviction path over real TCP worker processes: the cheat, the
/// divergent replay, the escalation, the slash, and the honest verdict all
/// survive the wire.
#[test]
fn tcp_cheating_committer_is_convicted_and_slashed() {
    let plans = [
        ("w0", FaultPlan::Tamper { step: Some(5), delta: 0.05 }),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
    ];
    let mut servers = Vec::new();
    let mut workers = Vec::new();
    for (name, plan) in plans {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
        let addr = listener.local_addr().unwrap();
        servers.push(spawn_server(listener, WorkerHost::new(name, plan), Some(1)));
        workers.push(PooledWorker::new(name, TcpEndpoint::connect(name, addr).unwrap()));
    }
    let pool = WorkerPool::new(workers);

    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_segments(4).with_audit(1.0)).wait();

    assert_eq!(outcome.accepted, Some(full), "{outcome:?}");
    assert!(outcome.eliminated >= 1);
    let s1 = &outcome.segments[1];
    assert!(s1.audit_escalated, "{s1:?}");
    assert_eq!(s1.slashed, 1000);

    let report = delegation.finish();
    assert_eq!(report.total_slashed(), 1000);
    assert_eq!(report.total_audit_escalated(), 1);

    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for server in servers {
        let _ = server.join();
    }
}

/// A sampled replay that can never run — the accused is the entire pool,
/// so no independent auditor exists — escalates unblamed: the stake is
/// released, the segment re-runs as (degenerate) replicated work, and the
/// job settles instead of wedging.
#[test]
fn impossible_replay_degrades_to_replication() {
    let pool = in_process_pool(&[("solo", FaultPlan::Honest)]);
    let spec = JobSpec::quick(Preset::Mlp, 6);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_audit(1.0)).wait();

    assert_eq!(outcome.accepted, Some(honest(spec)), "{outcome:?}");
    assert_eq!(outcome.segments.len(), 1);
    let s = &outcome.segments[0];
    assert!(s.audit_sampled, "the commitment was sampled");
    assert!(!s.audit_passed, "no replay ever ran");
    assert!(s.audit_escalated, "the impossible audit escalated");
    assert_eq!(s.slashed, 0, "an unblamed escalation never slashes");

    let report = delegation.finish();
    assert_eq!(report.total_audit_escalated(), 1);
    assert_eq!(report.total_slashed(), 0);
    let solo = report.stakes.iter().find(|s| s.worker == "solo").expect("enrolled");
    assert_eq!(solo.locked, 0, "the stake was released when blame evaporated");
    assert_eq!(solo.slashed, 0);
    assert_eq!(pool.idle(), 1);
}
