//! End-to-end acceptance for the observability plane: a sharded,
//! transfer-enabled job over real TCP whose registry counters and span
//! timeline reconcile **exactly** with the `ServiceReport` /
//! `SegmentOutcome` totals; a tamper-upload run where the rejection shows
//! up in both the registry and the report; the live `Request::Stats` wire
//! path through a serving frontend; and the gated RepOps kernel timers.

use std::net::TcpListener;

use verde::model::Preset;
use verde::net::tcp::{spawn_server, spawn_server_threaded, TcpEndpoint};
use verde::net::Endpoint;
use verde::obs::{Stage, STATS_VERSION};
use verde::service::{
    Delegation, DelegationFrontend, FaultPlan, JobRequest, PooledWorker, ServiceConfig,
    WorkerHost, WorkerPool,
};
use verde::train::JobSpec;
use verde::verde::protocol::{Request, Response};
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

/// THE acceptance run: a sharded, transfer-enabled job over real TCP with
/// tracing on. Every `coord_*` counter must equal the corresponding
/// report/outcome total, and the span timeline must carry exactly the
/// lifecycle events the settled segments imply.
#[test]
fn sharded_transfer_stats_reconcile_exactly_with_report_over_tcp() {
    let k = 2usize;
    let segments = 4usize;
    let mut servers = Vec::new();
    let mut workers = Vec::new();
    for name in ["w0", "w1"] {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
        let addr = listener.local_addr().unwrap();
        servers.push(spawn_server(listener, WorkerHost::new(name, FaultPlan::Honest), Some(1)));
        workers.push(PooledWorker::new(name, TcpEndpoint::connect(name, addr).unwrap()));
    }
    let pool = WorkerPool::new(workers);

    let spec = JobSpec::quick(Preset::Mlp, 8);
    let delegation = Delegation::start(&pool, ServiceConfig::new(k));
    let registry = delegation.registry().clone();
    registry.spans().enable();

    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(segments as u64).with_state_transfer())
        .wait();
    assert!(outcome.accepted.is_some(), "{outcome:?}");
    assert_eq!(outcome.segments.len(), segments);
    let report = delegation.finish();
    let snap = registry.snapshot();

    // --- counter ↔ report reconciliation: exact equality -------------
    assert_eq!(snap.version, STATS_VERSION);
    assert_eq!(snap.counter("coord_jobs_submitted"), 1);
    assert_eq!(snap.counter("coord_jobs_resolved"), 1);
    assert_eq!(snap.counter("coord_jobs_cancelled"), 0);
    assert_eq!(snap.counter("coord_segments_settled"), segments as u64);
    assert_eq!(snap.counter("coord_disputes"), report.total_disputes() as u64);
    assert_eq!(snap.counter("coord_eliminated"), report.total_eliminated() as u64);
    assert_eq!(snap.counter("coord_requeues"), report.total_requeued());
    assert_eq!(snap.counter("coord_steps_trained"), report.total_steps_trained());
    assert_eq!(snap.counter("coord_seeded_segments"), report.total_seeded_segments() as u64);
    assert_eq!(snap.counter("coord_transfer_bytes"), report.total_transfer_bytes());
    assert_eq!(snap.counter("coord_uploads_rejected"), report.total_uploads_rejected());
    assert_eq!(snap.counter("coord_bytes"), report.total_bytes());
    let report_requests: u64 = report.outcomes.iter().map(|o| o.requests).sum();
    assert_eq!(snap.counter("coord_requests"), report_requests);
    assert!(report.total_transfer_bytes() > 0, "transfer ran");
    assert_eq!(report.total_seeded_segments(), segments - 1);

    // --- tick instrumentation and end-of-run gauges ------------------
    let ticks = snap.histogram("coord_tick_us").expect("tick histogram registered");
    assert!(ticks.count > 0, "the event loop observed its ticks");
    assert_eq!(ticks.buckets.iter().sum::<u64>(), ticks.count);
    assert_eq!(snap.gauge("coord_queue_depth"), 0, "drained at shutdown");
    assert_eq!(snap.gauge("coord_active_segments"), 0);
    assert_eq!(snap.gauge("coord_pool_size"), 2);

    // --- span timeline ↔ segment outcomes ----------------------------
    // Honest fleet ⇒ no requeues, so event counts are exact.
    assert_eq!(report.total_requeued(), 0, "{report:?}");
    let spans = registry.spans();
    assert_eq!(spans.count(Stage::Submit), 1);
    assert_eq!(spans.count(Stage::Queue), segments);
    assert_eq!(spans.count(Stage::Lease), segments, "one lease per segment dispatch");
    assert_eq!(spans.count(Stage::Dispatch), k * segments, "k dispatch events per lease");
    assert_eq!(spans.count(Stage::Seed), segments - 1, "every non-first segment was seeded");
    assert_eq!(spans.count(Stage::Fetch), segments - 1, "one fetch per successor seed");
    assert_eq!(spans.count(Stage::Verify), segments - 1, "every fetch Merkle-verified");
    assert_eq!(spans.count(Stage::Verdict), segments);
    assert_eq!(
        spans.count(Stage::Settle),
        segments + 1,
        "one settle per segment plus the job-level settle"
    );
    assert_eq!(spans.job_latencies().len(), 1);

    // Per-segment: the lifecycle is ordered on the monotonic clock and
    // the k dispatch events name the final lease's workers.
    let events = spans.events();
    for s in &outcome.segments {
        let seg = Some(s.seg as u64);
        let lease =
            events.iter().find(|e| e.seg == seg && e.stage == Stage::Lease).expect("lease");
        let verdict =
            events.iter().find(|e| e.seg == seg && e.stage == Stage::Verdict).expect("verdict");
        let settle =
            events.iter().find(|e| e.seg == seg && e.stage == Stage::Settle).expect("settle");
        assert!(lease.at <= verdict.at && verdict.at <= settle.at, "segment {}", s.seg);
        assert_eq!(verdict.worker, s.winner, "verdict event names the winner");
        let dispatched: Vec<&str> = events
            .iter()
            .filter(|e| e.seg == seg && e.stage == Stage::Dispatch)
            .filter_map(|e| e.worker.as_deref())
            .collect();
        assert_eq!(dispatched.len(), k);
        for w in &s.workers {
            assert!(dispatched.contains(&w.as_str()), "{w} missing from dispatch events");
        }
    }

    // --- cross-cutting layers left monotonic evidence ----------------
    let g = verde::obs::global();
    assert!(g.counter("net_tcp_bytes_out").get() > 0, "TCP byte accounting fed the plane");
    assert!(g.counter("net_tcp_bytes_in").get() > 0);
    assert!(g.counter("net_tcp_requests_served").get() > 0);
    assert!(g.counter("trainer_steps").get() > 0, "worker-side training counted globally");

    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for server in servers {
        let _ = server.join();
    }
}

/// The tamper satellite: a bit-flipped checkpoint upload is rejected by
/// Merkle verification, and the rejection is visible in BOTH the segment
/// outcome / report and the delegation's registry.
#[test]
fn tampered_upload_counts_in_both_registry_and_report() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::TamperUpload),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let registry = delegation.registry().clone();
    registry.spans().enable();

    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(2).with_state_transfer())
        .wait();
    assert!(outcome.accepted.is_some(), "{outcome:?}");
    let report = delegation.finish();
    let snap = registry.snapshot();

    assert_eq!(report.total_uploads_rejected(), 1, "the bit-flip was caught");
    assert_eq!(snap.counter("coord_uploads_rejected"), report.total_uploads_rejected());
    let seg_revoked: u64 =
        outcome.segments.iter().map(|s| s.revoked as u64).sum();
    assert!(seg_revoked >= 1, "the tamperer lost its lease");
    assert_eq!(snap.counter("coord_revoked"), seg_revoked);
    assert_eq!(snap.counter("coord_seeded_segments"), 1, "the survivor still seeded seg 1");
    assert_eq!(snap.counter("coord_transfer_bytes"), report.total_transfer_bytes());
    // Span counts still reconcile with the settled segments.
    assert_eq!(registry.spans().count(Stage::Settle), outcome.segments.len() + 1);
    assert_eq!(registry.spans().count(Stage::Verdict), outcome.segments.len());
}

/// The audit-tier satellite: an optimistic job whose pinned committer
/// cheats, so every audit instrument fires — sampled, passed, escalated,
/// steps, and a slash. Each `coord_audit_*` / `coord_stake_*` instrument
/// must equal the corresponding `ServiceReport` total exactly, and the
/// audit spans must name the accused committer.
#[test]
fn audit_counters_reconcile_exactly_with_report() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Tamper { step: Some(5), delta: 0.05 }),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let registry = delegation.registry().clone();
    registry.spans().enable();

    let outcome = delegation.submit(JobRequest::new(spec).with_segments(4).with_audit(1.0)).wait();
    assert!(outcome.accepted.is_some(), "{outcome:?}");
    let report = delegation.finish();
    let snap = registry.snapshot();

    // The scenario exercises every instrument: segment 0's replay passes,
    // segment 1's diverges, escalates, and slashes.
    assert_eq!(report.total_audit_sampled(), 2, "{report:?}");
    assert_eq!(report.total_audit_passed(), 1);
    assert_eq!(report.total_audit_escalated(), 1);
    assert!(report.total_audit_steps() > 0);
    assert!(report.total_slashed() > 0);

    // --- counter ↔ report reconciliation: exact equality -------------
    assert_eq!(snap.counter("coord_audit_sampled"), report.total_audit_sampled() as u64);
    assert_eq!(snap.counter("coord_audit_passed"), report.total_audit_passed() as u64);
    assert_eq!(snap.counter("coord_audit_escalated"), report.total_audit_escalated() as u64);
    assert_eq!(snap.counter("coord_audit_steps"), report.total_audit_steps());
    assert_eq!(snap.counter("coord_stake_slashed"), report.total_slashed());
    // The segment-level bill and the ledger agree on every confiscation.
    let ledger_slashed: u64 = report.stakes.iter().map(|s| s.slashed).sum();
    assert_eq!(report.total_slashed(), ledger_slashed, "segment bill == ledger bill");
    assert_eq!(snap.gauge("coord_stake_locked"), 0, "every lock was released or slashed");

    // --- audit spans: one per dispatched replay, naming the accused ---
    let audits: Vec<_> = registry
        .spans()
        .events()
        .into_iter()
        .filter(|e| e.stage == Stage::Audit)
        .collect();
    assert_eq!(audits.len(), 2, "both sampled segments dispatched a replay");
    for a in &audits {
        assert_eq!(a.worker.as_deref(), Some("w0"), "the audit span names the accused");
    }
    // The settled timeline still reconciles segment-for-segment.
    assert_eq!(registry.spans().count(Stage::Settle), outcome.segments.len() + 1);
    assert_eq!(registry.spans().count(Stage::Verdict), outcome.segments.len());
}

/// The live stats plane over the wire: a serving frontend built
/// `with_stats` answers `Request::Stats` with the delegation's snapshot;
/// one built without it refuses rather than serving an empty lie.
#[test]
fn frontend_serves_live_stats_over_tcp() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let spec = JobSpec::quick(Preset::Mlp, 4);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec)).wait();
    assert!(outcome.accepted.is_some());

    // Without the stats plane: an explicit refusal.
    let mut bare = DelegationFrontend::new("bare", delegation.client());
    match bare.call(Request::Stats) {
        Response::Refuse(why) => assert!(why.contains("stats plane"), "{why}"),
        other => panic!("expected refusal, got {other:?}"),
    }

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let frontend = DelegationFrontend::new("coordinator", delegation.client())
        .with_stats(delegation.registry().clone());
    let server = spawn_server_threaded(listener, frontend, Some(1));

    let mut ep = TcpEndpoint::connect("coordinator", addr).unwrap();
    match ep.call(Request::Stats) {
        Response::Stats(snap) => {
            assert_eq!(snap.version, STATS_VERSION);
            assert_eq!(snap.counter("coord_jobs_submitted"), 1);
            assert_eq!(snap.counter("coord_jobs_resolved"), 1);
            assert!(snap.histogram("coord_tick_us").is_some());
            // Both renderers handle a real snapshot.
            assert!(snap.to_json().contains("\"coord_jobs_resolved\":1"));
            assert!(snap.to_prometheus().contains("coord_jobs_resolved 1"));
        }
        other => panic!("expected stats, got {other:?}"),
    }
    drop(ep);
    let _ = server.join();
    delegation.finish();
}

/// The dormant `tensor/profile.rs` hook: once kernel timing is enabled,
/// RepOps operator executions land in the global `repops_*` histograms.
#[test]
fn kernel_timing_surfaces_repops_histograms() {
    let g = verde::obs::global();
    let before = g.counter("repops_ops").get();
    verde::obs::enable_kernel_timing();
    let mut t = TrainerNode::honest("kt", JobSpec::quick(Preset::Mlp, 2));
    t.train();
    assert!(g.counter("repops_ops").get() > before, "operators were timed");
    let snap = g.snapshot();
    let h = snap.histogram("repops_matmul_us").expect("matmul timings recorded");
    assert!(h.count > 0);
    assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
}
