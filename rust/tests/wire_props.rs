//! Property tests over the wire codec: arbitrary protocol messages —
//! including multi-rank tensors and deep Merkle proofs — survive
//! `encode → decode` bit-exactly, `wire_size()` always equals the encoded
//! length, and truncated/corrupted frames return errors instead of
//! panicking.

use std::time::Duration;

use verde::graph::autodiff::Optimizer;
use verde::graph::executor::AugmentedCGNode;
use verde::hash::merkle::MerkleProof;
use verde::hash::Hash;
use verde::model::Preset;
use verde::obs::{HistogramSnapshot, Snapshot};
use verde::service::journal::{self, JournalEntry, MAX_JOURNAL_ENTRY};
use verde::service::{JobOutcome, SegmentOutcome};
use verde::tensor::Tensor;
use verde::train::JobSpec;
use verde::util::proptest::{forall, Gen};
use verde::verde::protocol::{
    BackendRequirement, InputProvenance, JobPolicy, RemoteStatus, Request, Response,
};
use verde::verde::wire::{CHECKPOINT_CHUNK, WireError};

fn gen_hash(g: &mut Gen) -> Hash {
    Hash::of_bytes(&g.u64().to_le_bytes())
}

fn gen_hashes(g: &mut Gen, max: usize) -> Vec<Hash> {
    let n = g.usize_in(0, max);
    (0..n).map(|_| gen_hash(g)).collect()
}

/// Finite but otherwise unconstrained payload values. NaN payloads would
/// also roundtrip bit-exactly, but canonical-bytes comparison is what the
/// properties check, so finite wide-exponent values suffice.
fn gen_tensor(g: &mut Gen) -> Tensor {
    let rank = g.usize_in(0, 4);
    let shape: Vec<usize> = (0..rank).map(|_| g.usize_in(1, 5)).collect();
    let numel = shape.iter().product();
    Tensor::new(shape, g.vec_f32_wide(numel))
}

fn gen_proof(g: &mut Gen, max_depth: usize) -> MerkleProof {
    MerkleProof {
        index: g.usize_in(0, 1 << 20),
        siblings: gen_hashes(g, max_depth),
    }
}

fn gen_node(g: &mut Gen) -> AugmentedCGNode {
    AugmentedCGNode {
        id: g.usize_in(0, 10_000),
        structure: gen_hash(g),
        input_hashes: gen_hashes(g, 6),
        output_hashes: gen_hashes(g, 3),
    }
}

fn gen_spec(g: &mut Gen) -> JobSpec {
    let preset = *g.pick(&[
        Preset::Mlp,
        Preset::LlamaTiny,
        Preset::LlamaTinyLora,
        Preset::LlamaSmall,
        Preset::LlamaBase,
        Preset::BertTiny,
        Preset::BertSmall,
    ]);
    let mut spec = JobSpec::quick(preset, g.usize_in(1, 100_000) as u64);
    spec.batch = g.usize_in(1, 64);
    spec.seq = g.usize_in(1, 256);
    spec.optimizer = if g.bool() {
        Optimizer::Adam {
            lr: g.f32_in(1e-5, 1.0),
            beta1: g.f32_in(0.0, 1.0),
            beta2: g.f32_in(0.0, 1.0),
            eps: g.f32_in(1e-10, 1e-4),
        }
    } else {
        Optimizer::Sgd { lr: g.f32_in(1e-5, 1.0) }
    };
    spec.weight_seed = g.u64();
    spec.data_seed = g.u64();
    spec.checkpoint_n = g.usize_in(1, 64) as u64;
    spec
}

fn gen_policy(g: &mut Gen) -> JobPolicy {
    JobPolicy {
        k: g.usize_in(0, 64),
        deadline: if g.bool() {
            Some(Duration::from_millis(g.usize_in(0, 10_000_000) as u64))
        } else {
            None
        },
        priority: g.u64() as i64,
        backend: if g.bool() {
            BackendRequirement::Any
        } else {
            BackendRequirement::ReproducibleOnly
        },
        segments: g.usize_in(1, 1 << 16) as u64,
        max_requeues: if g.bool() { Some(g.usize_in(0, 1000) as u32) } else { None },
        transfer: g.bool(),
        // Quantized to hundredths: every generated rate is in the codec's
        // canonical [0, 1] range, so roundtrips are bit-exact (the encoder
        // clamp never fires).
        audit_rate: g.usize_in(0, 100) as f32 / 100.0,
    }
}

/// A structurally valid `(total_chunks, chunk, payload)` triple for the
/// checkpoint-transfer messages (the codec rejects everything else).
fn gen_chunk(g: &mut Gen) -> (u64, u64, Vec<u8>) {
    let total = g.usize_in(1, 8) as u64;
    let chunk = g.usize_in(0, total as usize - 1) as u64;
    let payload = (0..g.usize_in(1, 300)).map(|_| (g.u64() & 0xff) as u8).collect();
    (total, chunk, payload)
}

/// A spec/boundary pair with the seed boundary strictly inside the job.
fn gen_seed_spec(g: &mut Gen) -> (JobSpec, u64) {
    let mut spec = gen_spec(g);
    if spec.steps < 2 {
        spec.steps = 2;
    }
    let start = g.usize_in(1, (spec.steps - 1) as usize) as u64;
    (spec, start)
}

fn gen_stat_name(g: &mut Gen) -> String {
    let n = g.usize_in(0, 24);
    (0..n).map(|_| char::from(b'a' + (g.u64() % 26) as u8)).collect()
}

fn gen_stat_pairs(g: &mut Gen, max: usize) -> Vec<(String, u64)> {
    let n = g.usize_in(0, max);
    (0..n).map(|_| (gen_stat_name(g), g.u64())).collect()
}

/// An arbitrary stats snapshot. Bucket vectors are generated at the
/// canonical `bounds.len() + 1` length the encoder always emits, so the
/// bit-exact roundtrip property holds.
fn gen_snapshot(g: &mut Gen) -> Snapshot {
    let n_hist = g.usize_in(0, 3);
    let histograms = (0..n_hist)
        .map(|_| {
            let n_bounds = g.usize_in(0, 6);
            let bounds: Vec<u64> = (0..n_bounds).map(|_| g.u64()).collect();
            let buckets: Vec<u64> = (0..=n_bounds).map(|_| g.u64()).collect();
            (
                gen_stat_name(g),
                HistogramSnapshot { bounds, buckets, sum: g.u64(), count: g.u64() },
            )
        })
        .collect();
    Snapshot {
        version: g.u64(),
        counters: gen_stat_pairs(g, 5),
        gauges: gen_stat_pairs(g, 5),
        histograms,
    }
}

fn gen_status(g: &mut Gen) -> RemoteStatus {
    match g.usize_in(0, 3) {
        0 => RemoteStatus::Unknown,
        1 => RemoteStatus::Queued,
        2 => RemoteStatus::Running { segments_done: g.u64(), segments_total: g.u64() },
        _ => RemoteStatus::Done {
            accepted: if g.bool() { Some(gen_hash(g)) } else { None },
            cancelled: g.bool(),
            disputes: g.u64(),
            eliminated: g.u64(),
        },
    }
}

fn gen_request(g: &mut Gen) -> Request {
    match g.usize_in(0, 16) {
        16 => Request::FetchManifest { step: g.u64() },
        15 => Request::CommitRoot { step: g.u64() },
        14 => Request::Stats,
        12 => {
            let chunk = g.usize_in(0, 1023) as u64;
            Request::FetchCheckpoint { step: g.u64(), chunk }
        }
        13 => {
            let (spec, start) = gen_seed_spec(g);
            let (total_chunks, chunk, payload) = gen_chunk(g);
            Request::SeedCheckpoint {
                spec,
                start,
                root: gen_hash(g),
                total_chunks,
                chunk,
                payload,
            }
        }
        0 => Request::FinalCommit,
        1 => Request::CheckpointHashes {
            boundaries: (0..g.usize_in(0, 40)).map(|_| g.u64()).collect(),
        },
        2 => Request::NodeHashSeq { step: g.u64() },
        3 => Request::OpenNode { step: g.u64(), idx: g.usize_in(0, 1 << 20) },
        4 => Request::InputProof { step: g.u64(), node_idx: g.usize_in(0, 1 << 20) },
        5 => Request::InputTensor {
            step: g.u64(),
            node_idx: g.usize_in(0, 1 << 20),
            input_idx: g.usize_in(0, 16),
        },
        6 => Request::Train { spec: gen_spec(g) },
        7 => Request::Ping,
        8 => Request::Submit { spec: gen_spec(g), policy: gen_policy(g) },
        9 => Request::Status { job_id: g.u64() },
        10 => Request::Cancel { job_id: g.u64() },
        _ => Request::Shutdown,
    }
}

fn gen_response(g: &mut Gen) -> Response {
    match g.usize_in(0, 14) {
        14 => {
            // The codec insists the chunk count match the declared byte
            // length, so generate the pair together.
            let n = g.usize_in(1, 8);
            let total_len = ((n - 1) * CHECKPOINT_CHUNK + g.usize_in(1, CHECKPOINT_CHUNK)) as u64;
            Response::Manifest {
                step: g.u64(),
                root: gen_hash(g),
                total_len,
                chunks: (0..n).map(|_| gen_hash(g)).collect(),
            }
        }
        13 => Response::Stats(gen_snapshot(g)),
        12 => {
            let (total_chunks, chunk, payload) = gen_chunk(g);
            Response::Checkpoint {
                step: g.u64(),
                root: gen_hash(g),
                total_chunks,
                chunk,
                payload,
            }
        }
        0 => Response::Commit(gen_hash(g)),
        1 => Response::Hashes(gen_hashes(g, 200)),
        2 => Response::NodeSeq(gen_hashes(g, 200)),
        3 => Response::Node(gen_node(g)),
        4 => {
            if g.bool() {
                Response::Proof(InputProvenance::Genesis {
                    leaf: gen_hash(g),
                    proof: gen_proof(g, 40),
                })
            } else {
                Response::Proof(InputProvenance::PrevStep {
                    node: gen_node(g),
                    out_idx: g.usize_in(0, 8),
                    proof: gen_proof(g, 40),
                })
            }
        }
        5 => Response::TensorPayload(gen_tensor(g)),
        6 => Response::Refuse(
            (0..g.usize_in(0, 60)).map(|_| char::from(b' ' + (g.u64() % 94) as u8)).collect(),
        ),
        7 => Response::Pong,
        8 => Response::Submitted { job_id: g.u64() },
        9 => Response::Status(gen_status(g)),
        10 => Response::Cancelled(g.bool()),
        _ => Response::Bye,
    }
}

fn gen_worker_name(g: &mut Gen) -> String {
    let n = g.usize_in(0, 16);
    (0..n).map(|_| char::from(b'a' + (g.u64() % 26) as u8)).collect()
}

fn gen_worker_names(g: &mut Gen, max: usize) -> Vec<String> {
    let n = g.usize_in(0, max);
    (0..n).map(|_| gen_worker_name(g)).collect()
}

fn gen_segment_outcome(g: &mut Gen) -> SegmentOutcome {
    SegmentOutcome {
        seg: g.usize_in(0, 1 << 20),
        start: g.u64(),
        end: g.u64(),
        accepted: if g.bool() { Some(gen_hash(g)) } else { None },
        winner: if g.bool() { Some(gen_worker_name(g)) } else { None },
        workers: gen_worker_names(g, 6),
        disputes: g.usize_in(0, 1 << 20),
        eliminated: g.usize_in(0, 1 << 20),
        requeues: g.usize_in(0, u32::MAX as usize) as u32,
        revoked: g.usize_in(0, 1 << 20),
        // The codec carries wall time as u64 nanoseconds, so a duration
        // generated from u64 nanos roundtrips bit-exactly.
        wall: Duration::from_nanos(g.u64()),
        bytes: g.u64(),
        requests: g.u64(),
        leased_seq: g.u64(),
        seeded_from: if g.bool() { Some(g.u64()) } else { None },
        steps_trained: g.u64(),
        transfer_bytes: g.u64(),
        uploads_rejected: g.usize_in(0, u32::MAX as usize) as u32,
        audit_sampled: g.bool(),
        audit_passed: g.bool(),
        audit_escalated: g.bool(),
        audit_steps: g.u64(),
        slashed: g.u64(),
    }
}

fn gen_job_outcome(g: &mut Gen) -> JobOutcome {
    let n_segs = g.usize_in(0, 4);
    JobOutcome {
        job_id: g.u64(),
        accepted: if g.bool() { Some(gen_hash(g)) } else { None },
        winner: if g.bool() { Some(gen_worker_name(g)) } else { None },
        cancelled: g.bool(),
        disputes: g.usize_in(0, 1 << 20),
        eliminated: g.usize_in(0, 1 << 20),
        requeues: g.usize_in(0, u32::MAX as usize) as u32,
        revoked: g.usize_in(0, 1 << 20),
        wall: Duration::from_nanos(g.u64()),
        bytes: g.u64(),
        requests: g.u64(),
        segments: (0..n_segs).map(|_| gen_segment_outcome(g)).collect(),
    }
}

fn gen_journal_entry(g: &mut Gen) -> JournalEntry {
    match g.usize_in(0, 9) {
        0 => JournalEntry::Submit { job_id: g.u64(), spec: gen_spec(g), policy: gen_policy(g) },
        1 => JournalEntry::Lease {
            job_id: g.u64(),
            seg_idx: g.u64(),
            lease_seq: g.u64(),
            workers: gen_worker_names(g, 8),
        },
        2 => JournalEntry::Revoke { worker: gen_worker_name(g) },
        3 => JournalEntry::SegmentSettled { job_id: g.u64(), outcome: gen_segment_outcome(g) },
        4 => JournalEntry::AuditCommit {
            job_id: g.u64(),
            seg_idx: g.u64(),
            worker: gen_worker_name(g),
            root: gen_hash(g),
        },
        5 => JournalEntry::AuditOutcome { job_id: g.u64(), seg_idx: g.u64(), passed: g.bool() },
        6 => JournalEntry::StakeLock { worker: gen_worker_name(g), amount: g.u64() },
        7 => JournalEntry::StakeRelease { worker: gen_worker_name(g) },
        8 => JournalEntry::StakeSlash { worker: gen_worker_name(g), amount: g.u64() },
        _ => JournalEntry::JobSettled { outcome: gen_job_outcome(g) },
    }
}

/// Frame an entry the way the journal file does: `u32` LE payload length
/// followed by the canonical payload.
fn frame(entry: &JournalEntry, out: &mut Vec<u8>) {
    let payload = entry.encode();
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
}

#[test]
fn prop_journal_entries_roundtrip_bit_exactly_and_size_exactly() {
    forall("journal entry encode→decode→encode is identity", 200, |g: &mut Gen| {
        let e = gen_journal_entry(g);
        let bytes = e.encode();
        assert_eq!(bytes.len(), e.wire_size(), "{e:?}");
        let back = JournalEntry::decode(&bytes).unwrap_or_else(|err| panic!("{e:?}: {err}"));
        assert_eq!(back, e);
        assert_eq!(back.encode(), bytes, "{e:?}: re-encode is canonical");
    });
}

#[test]
fn prop_journal_entry_truncations_and_corruption_are_total() {
    forall("journal entries are total over hostile bytes", 120, |g: &mut Gen| {
        let bytes = gen_journal_entry(g).encode();
        // Every strict prefix is rejected (all fields demanded by fixed
        // layout or a length prefix).
        let mut cuts = vec![0usize];
        for _ in 0..16.min(bytes.len().saturating_sub(1)) {
            cuts.push(g.usize_in(0, bytes.len() - 1));
        }
        for cut in cuts {
            assert!(
                JournalEntry::decode(&bytes[..cut]).is_err(),
                "prefix {cut}/{} accepted",
                bytes.len()
            );
        }
        // Trailing junk is rejected: the length prefix frames exactly one
        // entry.
        let mut padded = bytes.clone();
        padded.push((g.u64() & 0xff) as u8);
        assert!(JournalEntry::decode(&padded).is_err(), "trailing byte accepted");
        // Single-bit corruption: an error or a value whose canonical
        // encoding is exactly the corrupted bytes — never a panic, never a
        // non-canonical acceptance.
        let mut corrupt = bytes.clone();
        let pos = g.usize_in(0, corrupt.len() - 1);
        corrupt[pos] ^= 1u8 << g.usize_in(0, 7);
        if let Ok(e) = JournalEntry::decode(&corrupt) {
            assert_eq!(e.encode(), corrupt, "non-canonical journal entry accepted");
        }
    });
}

#[test]
fn prop_journal_replay_tolerates_torn_tail_never_corruption() {
    forall("replay: torn tail tolerated, corruption rejected", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 6);
        let entries: Vec<JournalEntry> = (0..n).map(|_| gen_journal_entry(g)).collect();
        let mut buf = Vec::new();
        for e in &entries {
            frame(e, &mut buf);
        }

        // Clean replay recovers every entry in order.
        let clean = journal::replay(&buf).expect("clean journal replays");
        assert_eq!(clean.entries, entries);
        assert_eq!(clean.torn_bytes, 0);

        // A crash mid-append truncates inside the final frame: replay keeps
        // every earlier entry and reports the torn remainder.
        let last_frame = 4 + entries.last().unwrap().wire_size();
        let cut = g.usize_in(buf.len() - last_frame + 1, buf.len() - 1);
        let torn = journal::replay(&buf[..cut]).expect("torn tail tolerated");
        assert_eq!(torn.entries, entries[..n - 1], "cut {cut}");
        assert_eq!(torn.torn_bytes, cut - (buf.len() - last_frame), "cut {cut}");

        // An absurd length prefix must be corruption (bounded allocation),
        // never treated as a frame to satisfy.
        let mut absurd = buf.clone();
        let huge = (MAX_JOURNAL_ENTRY as u32) + 1 + (g.u64() % 1024) as u32;
        absurd[0..4].copy_from_slice(&huge.to_le_bytes());
        assert!(
            matches!(journal::replay(&absurd), Err(WireError::FrameTooLarge { .. })),
            "absurd frame length accepted"
        );
    });
}

#[test]
fn prop_requests_roundtrip_bit_exactly_and_size_exactly() {
    forall("request encode→decode→encode is identity", 200, |g: &mut Gen| {
        let req = gen_request(g);
        let bytes = req.encode();
        assert_eq!(bytes.len(), req.wire_size(), "{req:?}");
        let back = Request::decode(&bytes).unwrap_or_else(|e| panic!("{req:?}: {e}"));
        assert_eq!(back.encode(), bytes, "{req:?}");
    });
}

#[test]
fn prop_responses_roundtrip_bit_exactly_and_size_exactly() {
    forall("response encode→decode→encode is identity", 200, |g: &mut Gen| {
        let resp = gen_response(g);
        let bytes = resp.encode();
        assert_eq!(bytes.len(), resp.wire_size(), "{resp:?}");
        let back = Response::decode(&bytes).unwrap_or_else(|e| panic!("{resp:?}: {e}"));
        assert_eq!(back.encode(), bytes, "{resp:?}");
    });
}

#[test]
fn prop_tensor_payload_values_survive() {
    forall("tensor payload bits survive the wire", 80, |g: &mut Gen| {
        let t = gen_tensor(g);
        let bytes = Response::TensorPayload(t.clone()).encode();
        match Response::decode(&bytes).unwrap() {
            Response::TensorPayload(back) => {
                assert!(back.bit_eq(&t), "shape {:?}", t.shape())
            }
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn prop_every_truncation_errors_never_panics() {
    forall("all strict prefixes are rejected", 60, |g: &mut Gen| {
        let bytes = if g.bool() { gen_request(g).encode() } else { gen_response(g).encode() };
        // sample up to 24 cut points (plus always the empty prefix)
        let mut cuts = vec![0usize];
        for _ in 0..24.min(bytes.len().saturating_sub(1)) {
            cuts.push(g.usize_in(0, bytes.len() - 1));
        }
        // A strict prefix can never be a complete message (every field is
        // demanded by fixed layout or a length prefix), and cross-decoding
        // fails on the disjoint tag spaces — so both decoders must error.
        for cut in cuts {
            assert!(
                Request::decode(&bytes[..cut]).is_err(),
                "request prefix {cut}/{} accepted",
                bytes.len()
            );
            assert!(
                Response::decode(&bytes[..cut]).is_err(),
                "response prefix {cut}/{} accepted",
                bytes.len()
            );
        }
    });
}

#[test]
fn prop_corrupted_bytes_never_panic_and_stay_canonical() {
    forall("single-byte corruption is safe", 120, |g: &mut Gen| {
        let bytes = if g.bool() { gen_request(g).encode() } else { gen_response(g).encode() };
        let mut corrupt = bytes.clone();
        let pos = g.usize_in(0, corrupt.len() - 1);
        let flip = 1u8 << g.usize_in(0, 7);
        corrupt[pos] ^= flip;
        // Decoding hostile bytes must be total: either a WireError or a
        // value whose canonical encoding is exactly the bytes we fed in.
        if let Ok(req) = Request::decode(&corrupt) {
            assert_eq!(req.encode(), corrupt, "non-canonical request accepted");
        }
        if let Ok(resp) = Response::decode(&corrupt) {
            assert_eq!(resp.encode(), corrupt, "non-canonical response accepted");
        }
    });
}

#[test]
fn deep_merkle_proof_roundtrips() {
    // A 64-level proof (a 2^64-leaf tree's worth of siblings).
    let proof = MerkleProof {
        index: usize::MAX >> 1,
        siblings: (0..64).map(|i| Hash::of_bytes(&[i as u8, 0xAA])).collect(),
    };
    let resp = Response::Proof(InputProvenance::Genesis {
        leaf: Hash::of_bytes(b"deep"),
        proof: proof.clone(),
    });
    let bytes = resp.encode();
    assert_eq!(bytes.len(), resp.wire_size());
    match Response::decode(&bytes).unwrap() {
        Response::Proof(InputProvenance::Genesis { proof: back, .. }) => {
            assert_eq!(back, proof);
        }
        other => panic!("{other:?}"),
    }
}

#[test]
fn prop_submit_policies_roundtrip_field_exact() {
    forall("submit policies survive delegation framing", 100, |g: &mut Gen| {
        let spec = gen_spec(g);
        let policy = gen_policy(g);
        let bytes = Request::Submit { spec, policy }.encode();
        assert_eq!(bytes.len(), Request::Submit { spec, policy }.wire_size());
        match Request::decode(&bytes).unwrap() {
            Request::Submit { spec: bspec, policy: bpol } => {
                assert_eq!(bspec.steps, spec.steps);
                assert_eq!(bspec.data_seed, spec.data_seed);
                assert_eq!(bpol.k, policy.k);
                assert_eq!(bpol.deadline, policy.deadline, "millisecond-exact deadlines");
                assert_eq!(bpol.priority, policy.priority);
                assert_eq!(bpol.backend, policy.backend);
                assert_eq!(bpol.segments, policy.segments);
                assert_eq!(bpol.max_requeues, policy.max_requeues);
                assert_eq!(bpol.transfer, policy.transfer);
                assert_eq!(
                    bpol.audit_rate.to_bits(),
                    policy.audit_rate.to_bits(),
                    "bit-exact audit rate"
                );
            }
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn prop_status_responses_roundtrip_field_exact() {
    forall("status answers survive the wire", 100, |g: &mut Gen| {
        let status = gen_status(g);
        let resp = Response::Status(status.clone());
        let bytes = resp.encode();
        assert_eq!(bytes.len(), resp.wire_size());
        match Response::decode(&bytes).unwrap() {
            Response::Status(back) => assert_eq!(back, status),
            other => panic!("{other:?}"),
        }
    });
}

#[test]
fn prop_checkpoint_transfer_messages_roundtrip_field_exact() {
    forall("fetch/checkpoint/seed messages survive the wire", 100, |g: &mut Gen| {
        let (spec, start) = gen_seed_spec(g);
        let (total_chunks, chunk, payload) = gen_chunk(g);
        let root = gen_hash(g);
        let seed = Request::SeedCheckpoint {
            spec,
            start,
            root,
            total_chunks,
            chunk,
            payload: payload.clone(),
        };
        let bytes = seed.encode();
        assert_eq!(bytes.len(), seed.wire_size());
        match Request::decode(&bytes).unwrap() {
            Request::SeedCheckpoint {
                spec: bspec,
                start: bstart,
                root: broot,
                total_chunks: btotal,
                chunk: bchunk,
                payload: bpayload,
            } => {
                assert_eq!(bspec.steps, spec.steps);
                assert_eq!(bspec.data_seed, spec.data_seed);
                assert_eq!(bstart, start);
                assert_eq!(broot, root);
                assert_eq!(btotal, total_chunks);
                assert_eq!(bchunk, chunk);
                assert_eq!(bpayload, payload);
            }
            other => panic!("{other:?}"),
        }

        let ck = Response::Checkpoint {
            step: g.u64(),
            root,
            total_chunks,
            chunk,
            payload: payload.clone(),
        };
        let bytes = ck.encode();
        assert_eq!(bytes.len(), ck.wire_size());
        match Response::decode(&bytes).unwrap() {
            Response::Checkpoint { payload: bpayload, root: broot, .. } => {
                assert_eq!(bpayload, payload);
                assert_eq!(broot, root);
            }
            other => panic!("{other:?}"),
        }

        // Hostile variants: oversized declared chunk counts, out-of-range
        // indices, zero-length payloads — errors, never panics or
        // allocations.
        let fetch = Request::FetchCheckpoint { step: 1, chunk: 1 << 62 };
        assert!(Request::decode(&fetch.encode()).is_err(), "absurd fetch chunk accepted");
    });
}

#[test]
fn prop_commit_root_and_audit_rate_survive_hostile_bytes() {
    forall("commitment messages are total over hostile bytes", 100, |g: &mut Gen| {
        // CommitRoot: size-exact, every strict prefix truncated, any junk
        // tail trailing — never a panic, never a silent reinterpretation.
        let req = Request::CommitRoot { step: g.u64() };
        let bytes = req.encode();
        assert_eq!(bytes.len(), req.wire_size(), "{req:?}");
        assert_eq!(Request::decode(&bytes).unwrap().encode(), bytes);
        for cut in 0..bytes.len() {
            assert!(Request::decode(&bytes[..cut]).is_err(), "prefix {cut} accepted");
        }
        let mut padded = bytes.clone();
        padded.push((g.u64() & 0xff) as u8);
        assert!(matches!(Request::decode(&padded), Err(WireError::Trailing { extra: 1 })));

        // The audit rate rides as the final 4 bytes of a Submit policy:
        // out-of-range and non-finite bit patterns must be rejected, not
        // accepted as a second spelling of "audits off".
        let submit = Request::Submit { spec: gen_spec(g), policy: gen_policy(g) };
        let good = submit.encode();
        let pos = good.len() - 4;
        for evil_rate in [1.0 + g.f32_in(0.001, 100.0), -g.f32_in(0.001, 100.0), f32::NAN] {
            let mut evil = good.clone();
            evil[pos..].copy_from_slice(&evil_rate.to_le_bytes());
            assert!(
                matches!(
                    Request::decode(&evil),
                    Err(WireError::Malformed { context: "policy.audit_rate" })
                ),
                "hostile audit_rate {evil_rate} accepted"
            );
        }
    });
}

#[test]
fn prop_job_specs_roundtrip_field_exact() {
    forall("job specs survive delegation framing", 100, |g: &mut Gen| {
        let spec = gen_spec(g);
        let bytes = Request::Train { spec }.encode();
        match Request::decode(&bytes).unwrap() {
            Request::Train { spec: back } => {
                assert_eq!(back.preset, spec.preset);
                assert_eq!(back.batch, spec.batch);
                assert_eq!(back.seq, spec.seq);
                assert_eq!(back.steps, spec.steps);
                assert_eq!(back.optimizer, spec.optimizer);
                assert_eq!(back.weight_seed, spec.weight_seed);
                assert_eq!(back.data_seed, spec.data_seed);
                assert_eq!(back.checkpoint_n, spec.checkpoint_n);
            }
            other => panic!("{other:?}"),
        }
    });
}
