//! Property tests over the protocol's core invariants: random fault
//! placements must always (a) be caught, (b) never convict the honest
//! trainer, (c) localize the dispute to the exact faulty step.

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::train::JobSpec;
use verde::util::proptest::{forall, Gen};
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn spec_with(steps: u64, n: u64) -> JobSpec {
    let mut spec = JobSpec::quick(Preset::Mlp, steps);
    spec.checkpoint_n = n;
    spec
}

#[test]
fn prop_random_tamper_always_convicts_cheater_never_honest() {
    forall("random tamper placements are caught", 12, |g: &mut Gen| {
        let steps = g.usize_in(4, 12) as u64;
        let n = g.usize_in(2, 5) as u64;
        let spec = spec_with(steps, n);
        let step = g.usize_in(1, steps as usize) as u64;
        // target any node of the extended graph with a tensor output whose
        // perturbation survives (update nodes always qualify)
        let session = verde::train::session::Session::new(spec);
        let updates: Vec<usize> =
            session.program.param_updates.values().map(|s| s.node).collect();
        let node = *g.pick(&updates);
        let delta = if g.bool() { 0.05 } else { -0.125 };
        let fault = Fault::TamperOutput { step, node, delta };

        let honest_first = g.bool();
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new("cheat", spec, Backend::Rep, fault);
        honest.train();
        cheat.train();
        let (r, cheater_idx) = if honest_first {
            (run_dispute(spec, honest, cheat), 1)
        } else {
            (run_dispute(spec, cheat, honest), 0)
        };
        assert_eq!(
            r.verdict.convicted(),
            Some(cheater_idx),
            "fault {fault:?}, honest_first={honest_first}, verdict {:?}",
            r.verdict
        );
        assert_eq!(r.diverging_step, Some(step), "fault {fault:?}");
    });
}

#[test]
fn prop_random_skip_and_data_faults_localized() {
    forall("skip/data faults localize to their step", 8, |g: &mut Gen| {
        let steps = g.usize_in(6, 14) as u64;
        let spec = spec_with(steps, g.usize_in(2, 6) as u64);
        let (fault, want_step) = if g.bool() {
            let after = g.usize_in(1, steps as usize - 1) as u64;
            (Fault::SkipSteps { after }, after + 1)
        } else {
            let s = g.usize_in(1, steps as usize) as u64;
            (Fault::WrongData { step: s }, s)
        };
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new("cheat", spec, Backend::Rep, fault);
        honest.train();
        cheat.train();
        let r = run_dispute(spec, honest, cheat);
        assert_eq!(r.verdict.convicted(), Some(1), "{fault:?}: {:?}", r.verdict);
        assert_eq!(r.diverging_step, Some(want_step), "{fault:?}");
    });
}

#[test]
fn prop_honest_pairs_never_dispute_across_seeds() {
    forall("honest pairs agree for any seed", 6, |g: &mut Gen| {
        let mut spec = spec_with(g.usize_in(3, 6) as u64, 3);
        spec.weight_seed = g.u64();
        spec.data_seed = g.u64();
        let mut a = TrainerNode::honest("a", spec);
        let mut b = TrainerNode::honest("b", spec);
        assert_eq!(a.train(), b.train());
    });
}
