//! Tournament over remote actors: k = 4 trainers (2 honest, 2 with
//! distinct faults) served through `net::threaded` mailboxes. The honest
//! claim must survive and the knockout must need at most
//! `distinct_claims − 1` disputes.

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::net::threaded::{spawn, Remote};
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::verde::faults::{first_update_node, Fault};
use verde::verde::tournament::run_tournament;
use verde::verde::trainer::TrainerNode;

fn trained(name: &str, spec: JobSpec, fault: Fault) -> TrainerNode {
    let mut t = TrainerNode::new(name, spec, Backend::Rep, fault);
    t.train();
    t
}

#[test]
fn k4_tournament_over_threaded_remotes() {
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let honest_commit = trained("ref", spec, Fault::None).final_commit();
    // a tamper target that provably diverges the state (an update node)
    let upd = first_update_node(&Session::new(spec).program).expect("no trainable params");

    let roster = [
        ("h0", Fault::None),
        ("h1", Fault::None),
        ("tamperer", Fault::TamperOutput { step: 2, node: upd, delta: 0.25 }),
        ("poisoner", Fault::WrongData { step: 4 }),
    ];
    let mut remotes: Vec<Remote> = roster
        .iter()
        .map(|(name, fault)| spawn(trained(name, spec, *fault)))
        .collect();

    let r = run_tournament(spec, &mut remotes);

    // The honest claim survives; both distinct cheats are exposed.
    assert_eq!(r.accepted, honest_commit);
    assert!(r.winner <= 1, "an honest trainer wins, got {}", r.winner);
    let eliminated: Vec<usize> = r.eliminated.iter().map(|(i, _)| *i).collect();
    assert!(eliminated.contains(&2), "tamperer exposed: {eliminated:?}");
    assert!(eliminated.contains(&3), "poisoner exposed: {eliminated:?}");
    assert_eq!(r.eliminated.len(), 2);

    // h0 and h1 merge into one claim: 3 distinct claims → ≤ 2 disputes.
    assert!(
        r.disputes <= 2,
        "disputes ({}) must be ≤ distinct_claims − 1 (2)",
        r.disputes
    );
    assert!(r.disputes >= 1, "distinct claims cannot merge without a dispute");
}

#[test]
fn k4_all_honest_over_remotes_needs_no_dispute() {
    let spec = JobSpec::quick(Preset::Mlp, 4);
    let mut remotes: Vec<Remote> = (0..4)
        .map(|i| spawn(trained(&format!("h{i}"), spec, Fault::None)))
        .collect();
    let r = run_tournament(spec, &mut remotes);
    assert_eq!(r.disputes, 0);
    assert!(r.eliminated.is_empty());
}
