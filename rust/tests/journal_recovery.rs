//! Kill-and-recover acceptance for the write-ahead job journal.
//!
//! The crash model: a SIGKILL at any instant leaves the journal file as a
//! prefix of what an uninterrupted run would have written (plus possibly a
//! torn final frame) — the WAL discipline (journal before acting, fsync at
//! settle boundaries) guarantees exactly that. So each test *constructs*
//! the post-crash file — a frame-prefix of a real run's journal, with
//! garbage appended as the torn tail — and drives `Delegation::recover`
//! over it with a fresh pool, asserting:
//!
//! - the recovered final verdict is **bit-identical** to the uninterrupted
//!   run's;
//! - only unsettled segments are re-trained (worker-step accounting via
//!   `coord_steps_trained`, which excludes replayed segments);
//! - the `StakeLedger` balances — stake locked behind an audit that died
//!   with the process is released, never leaked;
//! - settled jobs re-serve their logged outcome without touching a worker.

use std::path::PathBuf;
use std::time::Duration;

use verde::model::Preset;
use verde::service::journal::{self, JournalEntry};
use verde::service::{
    Delegation, FaultPlan, JobPolicy, JobRequest, JobStatus, PooledWorker, ServiceConfig,
    WorkerHost, WorkerPool,
};
use verde::train::checkpoint::split_points;
use verde::train::JobSpec;
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

fn honest_pair() -> WorkerPool {
    in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)])
}

fn wal_path(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("verde-recovery-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    dir.join(format!("{tag}.wal"))
}

/// Re-frame `entries` exactly the way the journal file does.
fn frame_all(entries: &[JournalEntry]) -> Vec<u8> {
    let mut out = Vec::new();
    for e in entries {
        let payload = e.encode();
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
    }
    out
}

/// The tentpole acceptance test: kill the coordinator after two of four
/// segments settled, recover, and get the uninterrupted run's verdict
/// bit-identically while re-training only the two unsettled segments.
#[test]
fn recovery_mid_job_is_bit_identical_and_retrains_only_unsettled_segments() {
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let k = 2usize;
    let segments = 4u64;

    // Uninterrupted reference run, journaled.
    let ref_path = wal_path("reference");
    let pool = honest_pair();
    let delegation =
        Delegation::start_durable(&pool, ServiceConfig::new(k), &ref_path).expect("durable start");
    let reference = delegation.submit(JobRequest::new(spec).with_segments(segments)).wait();
    delegation.finish();
    assert!(reference.accepted.is_some(), "{reference:?}");
    assert_eq!(reference.segments.len(), 4);

    // Construct the post-SIGKILL file: every frame up to and including the
    // second settled segment, then garbage as the torn tail of a frame the
    // crash interrupted.
    let full = journal::replay(&std::fs::read(&ref_path).expect("journal bytes"))
        .expect("reference journal replays");
    let mut settled_seen = 0usize;
    let cut = full
        .entries
        .iter()
        .position(|e| {
            if matches!(e, JournalEntry::SegmentSettled { .. }) {
                settled_seen += 1;
            }
            settled_seen == 2
        })
        .expect("reference run settled at least 2 segments");
    let mut crashed = frame_all(&full.entries[..=cut]);
    crashed.extend_from_slice(&[0x2a, 0x00, 0x00]); // torn: 3 bytes of a length prefix
    let crash_path = wal_path("crashed");
    std::fs::write(&crash_path, &crashed).expect("write crash journal");

    // Recover on a fresh pool (the old connections died with the process).
    let pool = honest_pair();
    let (recovered, handles) =
        Delegation::recover(&pool, ServiceConfig::new(k), &crash_path).expect("recover");
    assert_eq!(handles.len(), 1, "one in-flight job to resume");
    assert_eq!(handles[0].id(), reference.job_id);

    let outcome = handles[0].wait();
    // Bit-identical final verdict, and every settled-from-log segment is
    // byte-for-byte the reference one (same certified root, same verdict,
    // even the same wall-clock accounting — it came off the journal).
    assert_eq!(outcome.accepted, reference.accepted, "recovered verdict diverged");
    assert_eq!(outcome.segments.len(), 4);
    assert_eq!(outcome.segments[0], reference.segments[0], "settled segment not trusted");
    assert_eq!(outcome.segments[1], reference.segments[1], "settled segment not trusted");
    for (seg, want) in outcome.segments.iter().zip(&reference.segments) {
        assert_eq!(seg.accepted, want.accepted, "segment {} root diverged", seg.seg);
    }
    assert!(!outcome.cancelled);

    // Worker-step accounting: only the two unsettled segments re-trained.
    // Without state transfer segment i re-trains its prefix [0, b_i], and
    // `coord_steps_trained` counts steps × leased workers for segments
    // settled *live* (replayed segments land in the replay counter).
    let bounds = split_points(0, spec.steps, segments);
    let expect_steps = (k as u64) * (bounds[2] + bounds[3]);
    let stats = recovered.stats();
    assert_eq!(
        stats.counter("coord_steps_trained"),
        expect_steps,
        "recovery re-trained settled work"
    );
    assert_eq!(stats.counter("coord_journal_replayed_segments"), 2);
    assert_eq!(stats.counter("coord_journal_recovered_jobs"), 1);
    assert!(stats.counter("coord_journal_entries") > 0, "recovered run journals new entries");

    // A second recovery from the (now further-grown) journal sees the job
    // settled: the fold is idempotent across crash generations.
    let report = recovered.finish();
    assert!(report.stakes.iter().all(|s| s.locked == 0), "locked stake leaked: {:?}", report.stakes);
    let pool = honest_pair();
    let (again, handles) =
        Delegation::recover(&pool, ServiceConfig::new(k), &crash_path).expect("second recover");
    assert_eq!(handles.len(), 1);
    let replayed = handles[0].wait();
    assert_eq!(replayed.accepted, reference.accepted);
    assert!(
        matches!(handles[0].try_status(), JobStatus::Done(_)),
        "settled job must re-serve without training"
    );
    assert_eq!(again.stats().counter("coord_steps_trained"), 0, "nothing left to train");
    again.finish();
}

/// A cleanly settled journal recovers to an already-`Done` handle with the
/// logged outcome byte-for-byte, and the id counter resumes past it.
#[test]
fn settled_job_reserves_logged_outcome_and_id_counter_resumes() {
    let path = wal_path("settled");
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let want = TrainerNode::honest("ref", spec).train();

    let pool = honest_pair();
    let delegation =
        Delegation::start_durable(&pool, ServiceConfig::new(2), &path).expect("durable start");
    let original = delegation.submit(JobRequest::new(spec).with_segments(2)).wait();
    assert_eq!(original.accepted, Some(want));
    delegation.finish();

    let pool = honest_pair();
    let (recovered, handles) =
        Delegation::recover(&pool, ServiceConfig::new(2), &path).expect("recover");
    assert_eq!(handles.len(), 1);
    // Already terminal — served from the log, no worker ever touched.
    assert!(matches!(handles[0].try_status(), JobStatus::Done(_)));
    let outcome = handles[0].wait();
    assert_eq!(outcome, original, "logged outcome must re-serve byte-for-byte");
    assert_eq!(recovered.stats().counter("coord_steps_trained"), 0);

    // The id counter resumes past every journaled id: a fresh submission
    // can never collide with a recovered handle.
    let mut spec2 = spec;
    spec2.data_seed ^= 0xD00D;
    let fresh = recovered.submit(JobRequest::new(spec2));
    assert_eq!(fresh.id(), original.job_id + 1, "job-id collision after recovery");
    assert!(fresh.wait().accepted.is_some());
    recovered.finish();
}

/// Stake locked behind an audit in flight at the crash is released on
/// recovery — journaled as a release, visible as a balanced ledger — and
/// the interrupted job still reaches the honest verdict.
#[test]
fn stake_locked_at_crash_is_released_not_leaked() {
    let spec = JobSpec::quick(Preset::Mlp, 4);
    let want = TrainerNode::honest("ref", spec).train();

    // Synthesize the crash journal directly: a submitted job plus a stake
    // lock with no matching release/slash — the audit died mid-flight.
    let entries = vec![
        JournalEntry::Submit { job_id: 5, spec, policy: JobPolicy::default() },
        JournalEntry::StakeLock { worker: "auditee".to_string(), amount: 700 },
    ];
    let mut bytes = frame_all(&entries);
    bytes.push(0x13); // torn single byte
    let path = wal_path("stake");
    std::fs::write(&path, &bytes).expect("write crash journal");

    let pool = honest_pair();
    let (recovered, handles) =
        Delegation::recover(&pool, ServiceConfig::new(2), &path).expect("recover");
    assert_eq!(handles.len(), 1);
    assert_eq!(handles[0].id(), 5);
    let outcome = handles[0].wait();
    assert_eq!(outcome.accepted, Some(want), "recovered job reaches the honest verdict");

    // The release was journaled at recovery (before any new work), and the
    // torn tail was truncated away — the file replays cleanly end to end.
    let replay = journal::replay(&std::fs::read(&path).expect("journal bytes"))
        .expect("post-recovery journal replays");
    assert_eq!(replay.torn_bytes, 0, "torn tail survived recovery");
    assert!(
        replay.entries.iter().any(
            |e| matches!(e, JournalEntry::StakeRelease { worker } if worker == "auditee")
        ),
        "stake release not journaled"
    );

    let report = recovered.finish();
    let auditee = report.stakes.iter().find(|s| s.worker == "auditee").expect("account restored");
    assert_eq!(auditee.locked, 0, "locked stake leaked through recovery");
    assert_eq!(auditee.slashed, 0);
    assert!(auditee.deposited > 0);
    assert!(report.stakes.iter().all(|s| s.locked == 0));
}

/// A missing journal file recovers to an empty, working delegation (the
/// `--journal PATH` cold-start path), and a journal whose *interior* is
/// corrupt — not merely torn — refuses to recover rather than silently
/// dropping history.
#[test]
fn missing_file_cold_starts_and_interior_corruption_refuses() {
    let path = wal_path("coldstart");
    std::fs::remove_file(&path).ok();
    let pool = honest_pair();
    let (delegation, handles) =
        Delegation::recover(&pool, ServiceConfig::new(2), &path).expect("cold start");
    assert!(handles.is_empty());
    let spec = JobSpec::quick(Preset::Mlp, 3);
    let handle = delegation.submit(JobRequest::new(spec));
    assert_eq!(handle.id(), 0, "cold start begins at id 0");
    assert!(handle.wait().accepted.is_some());
    delegation.finish();

    // The journal now has real history; flip a byte inside the FIRST frame
    // (a complete entry, so this is corruption, not a torn tail).
    let mut bytes = std::fs::read(&path).expect("journal bytes");
    assert!(bytes.len() > 8);
    bytes[4] ^= 0xFF; // first payload byte: the entry tag
    std::fs::write(&path, &bytes).expect("rewrite");
    let pool = honest_pair();
    let err = Delegation::recover(&pool, ServiceConfig::new(2), &path)
        .err()
        .expect("corrupt interior must refuse recovery");
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
}

/// Crash-point sweep: recovery from *every* whole-entry prefix of a real
/// journal reaches the reference verdict — there is no instant at which a
/// SIGKILL strands the job or forks the verdict.
#[test]
fn every_crash_point_recovers_to_the_reference_verdict() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let path = wal_path("sweep-ref");
    let pool = honest_pair();
    let delegation =
        Delegation::start_durable(&pool, ServiceConfig::new(2), &path).expect("durable start");
    let reference = delegation.submit(JobRequest::new(spec).with_segments(2)).wait();
    delegation.finish();
    let accepted = reference.accepted.expect("reference verdict");

    let full = journal::replay(&std::fs::read(&path).expect("journal bytes")).expect("replay");
    // Prefixes that contain the Submit (before it there is no job to
    // recover — cold start, covered elsewhere).
    for cut in 1..=full.entries.len() {
        let crash_path = wal_path(&format!("sweep-{cut}"));
        std::fs::write(&crash_path, frame_all(&full.entries[..cut])).expect("write prefix");
        let pool = honest_pair();
        let (recovered, handles) = Delegation::recover(&pool, ServiceConfig::new(2), &crash_path)
            .unwrap_or_else(|e| panic!("prefix {cut}: {e}"));
        assert_eq!(handles.len(), 1, "prefix {cut}");
        let outcome = handles[0].wait();
        assert_eq!(outcome.accepted, Some(accepted), "prefix {cut} forked the verdict");
        assert!(!outcome.cancelled, "prefix {cut}");
        let report = recovered.finish();
        assert!(report.stakes.iter().all(|s| s.locked == 0), "prefix {cut} leaked stake");
        std::fs::remove_file(&crash_path).ok();
    }
}

/// Waiting on a handle `recover` returned for a job the journal shows
/// settled returns instantly — even against a pool whose only worker
/// tampers with every job — proof the outcome is served from the log, not
/// from work.
#[test]
fn settled_outcome_serves_from_log_without_touching_workers() {
    let path = wal_path("no-workers");
    let spec = JobSpec::quick(Preset::Mlp, 4);
    let pool = honest_pair();
    let delegation =
        Delegation::start_durable(&pool, ServiceConfig::new(2), &path).expect("durable start");
    let original = delegation.submit(JobRequest::new(spec)).wait();
    assert!(original.accepted.is_some());
    delegation.finish();

    // A pool that could only ever produce a *wrong* answer: if recovery
    // re-trained the settled job, the verdict would change or hang.
    let tamperers = in_process_pool(&[("evil", FaultPlan::Tamper { step: Some(0), delta: 1.0 })]);
    let (recovered, handles) =
        Delegation::recover(&tamperers, ServiceConfig::new(1), &path).expect("recover");
    assert_eq!(handles.len(), 1);
    let t0 = std::time::Instant::now();
    assert_eq!(handles[0].wait(), original);
    assert!(t0.elapsed() < Duration::from_secs(5), "served from log, not re-trained");
    assert_eq!(recovered.stats().counter("coord_steps_trained"), 0, "a worker was dispatched");
    recovered.finish();
}
