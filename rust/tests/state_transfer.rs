//! Acceptance battery for verified checkpoint state-transfer between
//! segments: a sharded job trains exactly `b_i − b_{i−1}` steps per
//! segment (asserted via step accounting in the report AND via worker-side
//! counters over real TCP), its final verdict equals the unsharded path's,
//! a bit-flipped checkpoint upload is rejected by Merkle verification and
//! recovered from via a survivor, and a cheater inside a seeded segment
//! forces the prefix-re-training fallback without poisoning the verdict.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use verde::hash::Hash;
use verde::model::Preset;
use verde::net::tcp::{spawn_server, TcpEndpoint};
use verde::net::Endpoint;
use verde::service::{
    Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::train::checkpoint::split_points;
use verde::train::JobSpec;
use verde::verde::protocol::Request;
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

fn honest(spec: JobSpec) -> Hash {
    TrainerNode::honest("ref", spec).train()
}

/// The acceptance criterion: with state transfer on, segment `i` executes
/// exactly `b_i − b_{i−1}` training steps, every boundary verdict still
/// equals the honest checkpoint commitment, and the rolled-up verdict
/// equals the unsharded path's.
#[test]
fn transfer_trains_delta_steps_and_matches_unsharded_verdict() {
    let plans = [
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
        ("w3", FaultPlan::Honest),
    ];
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);
    let boundaries = split_points(0, 12, 4);

    // Baseline: the same sharded job WITHOUT transfer pays the prefix
    // re-training bill (k × Σ b_i worker-steps).
    let pool = in_process_pool(&plans);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let prefix_outcome = delegation.submit(JobRequest::new(spec).with_segments(4)).wait();
    assert_eq!(prefix_outcome.accepted, Some(full));
    let prefix_report = delegation.finish();
    let prefix_steps = prefix_report.total_steps_trained();
    assert_eq!(prefix_steps, 2 * boundaries.iter().sum::<u64>(), "prefix mode re-trains prefixes");

    // State transfer: fresh pool, same job.
    let pool = in_process_pool(&plans);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(4).with_state_transfer())
        .wait();

    assert!(!outcome.cancelled);
    assert_eq!(outcome.accepted, Some(full), "transfer == unsharded verdict: {outcome:?}");
    assert_eq!(outcome.segments.len(), 4);
    let ends: Vec<u64> = outcome.segments.iter().map(|s| s.end).collect();
    assert_eq!(ends, boundaries);
    for (i, s) in outcome.segments.iter().enumerate() {
        assert_eq!(s.accepted, Some(honest(spec.prefix(s.end))), "segment {i}");
        assert_eq!(s.workers.len(), 2, "k = 2 per segment");
        assert_eq!(s.disputes, 0);
        assert_eq!(s.requeues, 0);
        assert_eq!(s.uploads_rejected, 0);
        // THE acceptance assertion: exactly b_i − b_{i−1} steps trained.
        assert_eq!(s.steps_trained, s.end - s.start, "segment {i} trains only its delta");
        if i == 0 {
            assert_eq!(s.seeded_from, None, "segment 0 starts from genesis");
        } else {
            assert_eq!(s.seeded_from, Some(boundaries[i - 1]), "segment {i} was seeded");
        }
        if i + 1 < outcome.segments.len() {
            assert!(s.transfer_bytes > 0, "segment {i} served a checkpoint fetch");
        }
    }

    let report = delegation.finish();
    assert_eq!(report.total_seeded_segments(), 3);
    assert_eq!(report.total_uploads_rejected(), 0);
    assert!(report.total_transfer_bytes() > 0);
    // Fleet-wide: k × steps worker-steps instead of k × Σ b_i.
    assert_eq!(report.total_steps_trained(), 2 * 12);
    assert!(
        report.total_steps_trained() < prefix_steps,
        "state transfer must beat prefix re-training: {} vs {prefix_steps}",
        report.total_steps_trained()
    );
    let json = report.to_json();
    assert!(json.contains("\"seeded_segments\":3"), "{json}");
    assert!(json.contains("\"steps_trained\":24"), "{json}");
    assert_eq!(pool.idle(), 4, "all leases returned");
}

/// Step accounting measured on the workers themselves, over real TCP:
/// each of the two workers trains every segment's delta exactly once, so
/// its own counter lands at `steps` (not `Σ b_i`), and the seeded
/// segments arrive via `SeedCheckpoint`.
#[test]
fn tcp_workers_train_only_deltas_under_transfer() {
    let plans = [("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)];
    let mut servers = Vec::new();
    let mut workers = Vec::new();
    for (name, plan) in plans {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
        let addr = listener.local_addr().unwrap();
        servers.push(spawn_server(listener, WorkerHost::new(name, plan), Some(1)));
        workers.push(PooledWorker::new(name, TcpEndpoint::connect(name, addr).unwrap()));
    }
    let pool = WorkerPool::new(workers);

    let spec = JobSpec::quick(Preset::Mlp, 8);
    let full = honest(spec);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(4).with_state_transfer())
        .wait();
    assert_eq!(outcome.accepted, Some(full), "{outcome:?}");
    assert_eq!(outcome.segments.len(), 4);
    delegation.finish();

    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for server in servers {
        let host = server.join().expect("worker thread");
        assert_eq!(
            host.counters.get("steps_trained"),
            8,
            "{}: trained k-th share of every delta, not the prefixes",
            host.name()
        );
        assert_eq!(host.counters.get("jobs_seeded"), 3, "{}", host.name());
    }
}

/// The tamper satellite: a worker serving a bit-flipped checkpoint upload
/// is caught by Merkle verification against the unanimous state root, its
/// lease is revoked, the fetch recovers from a surviving co-winner, and
/// the final verdict still matches the unsharded path.
#[test]
fn tampered_upload_is_rejected_and_fetch_recovers_on_survivor() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::TamperUpload),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(2).with_state_transfer())
        .wait();

    assert_eq!(outcome.accepted, Some(full), "verdict unharmed: {outcome:?}");
    assert_eq!(outcome.segments.len(), 2);
    let s0 = &outcome.segments[0];
    // w0 trains honestly, so segment 0's tournament is clean — the attack
    // only surfaces at upload time.
    assert_eq!(s0.disputes, 0);
    assert_eq!(s0.uploads_rejected, 1, "the bit-flipped upload was caught");
    assert!(s0.revoked >= 1, "the tamperer lost its lease");
    let s1 = &outcome.segments[1];
    assert_eq!(s1.seeded_from, Some(4), "the survivor's upload seeded segment 1");
    assert_eq!(s1.steps_trained, 4);
    assert_eq!(s1.requeues, 0, "no fallback needed — a co-winner had the real state");

    let report = delegation.finish();
    assert_eq!(report.total_uploads_rejected(), 1);
    assert!(report.revoked.contains(&"w0".to_string()), "{:?}", report.revoked);
    assert_eq!(pool.size(), 2, "the tamperer is gone for good");
    assert_eq!(pool.idle(), 2);
}

/// A cheater *inside* a seeded segment: seeded leases cannot run the
/// bisection dispute (no trajectory below the seed), so disagreement falls
/// the segment back to prefix re-training, where the full dispute protocol
/// convicts the cheater — and the final verdict still matches the
/// unsharded path. Optimistic fast path, pessimistic fallback.
#[test]
fn seeded_disagreement_falls_back_to_prefix_and_convicts() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Tamper { step: Some(11), delta: 0.05 }),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let t0 = Instant::now();
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(2).with_state_transfer())
        .wait();
    assert!(t0.elapsed() < Duration::from_secs(120), "fallback must not wedge the job");

    assert_eq!(outcome.accepted, Some(full), "{outcome:?}");
    assert_eq!(outcome.segments.len(), 2);
    let s1 = &outcome.segments[1];
    assert_eq!(s1.requeues, 1, "the seeded lease disagreed and fell back once");
    assert_eq!(s1.seeded_from, None, "the settling attempt re-trained the prefix");
    assert_eq!(s1.steps_trained, 12, "fallback pays the full prefix");
    assert!(s1.disputes >= 1, "the fallback tournament ran a real dispute");
    assert!(outcome.eliminated >= 1, "the cheater was convicted");
    assert_eq!(outcome.winner.as_deref(), Some("w0"));

    let report = delegation.finish();
    assert_eq!(pool.idle(), 2, "eliminations are not revocations; leases returned");
    assert!(report.revoked.is_empty());
}

/// `segments == 1` with transfer requested behaves exactly like an
/// unsharded job: nothing to seed, nothing fetched.
#[test]
fn single_segment_transfer_degenerates_to_unsharded() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let spec = JobSpec::quick(Preset::Mlp, 5);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_state_transfer()).wait();
    assert_eq!(outcome.accepted, Some(honest(spec)));
    assert_eq!(outcome.segments.len(), 1);
    let s = &outcome.segments[0];
    assert_eq!(s.seeded_from, None);
    assert_eq!(s.steps_trained, 5);
    assert_eq!(s.transfer_bytes, 0);
    let report = delegation.finish();
    assert_eq!(report.total_seeded_segments(), 0);
    assert_eq!(report.total_transfer_bytes(), 0);
}
