//! Cross-backend reproducibility (the heart of the RepOps claim, §3):
//! the SAME logical program executed by two entirely different stacks —
//! the Rust RepOps engine and the XLA-compiled Pallas kernel — must
//! produce bitwise-identical results.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! message) otherwise so plain `cargo test` stays green pre-AOT.
//! The whole file is gated on the `pjrt` feature (the PJRT runtime needs
//! the `xla` crate from the rust_pallas toolchain image).
#![cfg(feature = "pjrt")]

use verde::runtime::{artifacts_present, default_dir, Runtime};
use verde::tensor::repops;
use verde::tensor::Tensor;

/// Wide-exponent inputs that expose any reduction-order difference.
fn adversarial(shape: &[usize], seed: u64) -> Tensor {
    let mut t = Tensor::rand(shape.to_vec(), seed, 1.0);
    for (i, v) in t.data_mut().iter_mut().enumerate() {
        let mag = ((i * 2654435761) % 24) as i32 - 12;
        *v *= (2.0f32).powi(mag);
    }
    t
}

fn skip() -> bool {
    if !artifacts_present() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return true;
    }
    false
}

#[test]
fn strict_kernel_bitwise_matches_rust_engine() {
    if skip() {
        return;
    }
    let rt = Runtime::cpu(default_dir()).unwrap();
    let manifest = rt.manifest().unwrap();
    let (m, k, n) = (
        manifest.cfg("xm") as usize,
        manifest.cfg("xk") as usize,
        manifest.cfg("xn") as usize,
    );
    let art = rt.load("repmatmul_strict.hlo.txt").unwrap();
    for seed in [1u64, 7, 42] {
        let x = adversarial(&[m, k], seed);
        let y = adversarial(&[k, n], seed + 100);
        let xla_out = &art.run_f32(&[&x, &y]).unwrap()[0];
        // the kernel's pinned FP sequence is fma(a,b,acc) ascending k —
        // implemented in Rust as repops::matmul_fma
        let rust_out = repops::matmul_fma(&x, &y);
        assert!(
            xla_out.bit_eq(&rust_out),
            "seed {seed}: XLA-compiled Pallas and Rust RepOps disagree bitwise \
             (max abs diff {})",
            xla_out.max_abs_diff(&rust_out)
        );
        // and the separate-rounding engine agrees to float tolerance
        let sep = repops::matmul(&x, &y);
        let scale = sep.data().iter().fold(0f32, |a, &b| a.max(b.abs()));
        assert!(xla_out.max_abs_diff(&sep) / scale < 1e-5);
    }
}

#[test]
fn xla_artifact_is_self_deterministic() {
    if skip() {
        return;
    }
    let rt = Runtime::cpu(default_dir()).unwrap();
    let manifest = rt.manifest().unwrap();
    let (m, k, n) = (
        manifest.cfg("xm") as usize,
        manifest.cfg("xk") as usize,
        manifest.cfg("xn") as usize,
    );
    let strict = rt.load("repmatmul_strict.hlo.txt").unwrap();
    let mxu = rt.load("repmatmul_mxu.hlo.txt").unwrap();
    let x = adversarial(&[m, k], 3);
    let y = adversarial(&[k, n], 4);
    for art in [&strict, &mxu] {
        let a = &art.run_f32(&[&x, &y]).unwrap()[0];
        let b = &art.run_f32(&[&x, &y]).unwrap()[0];
        assert!(a.bit_eq(b), "{} not self-deterministic", art.name);
    }
    // both kernels agree numerically (different reduction trees → approx)
    let a = &strict.run_f32(&[&x, &y]).unwrap()[0];
    let b = &mxu.run_f32(&[&x, &y]).unwrap()[0];
    let scale = a.data().iter().fold(0f32, |acc, &v| acc.max(v.abs()));
    assert!(a.max_abs_diff(b) <= 1e-2 * scale);
}

#[test]
fn model_forward_artifact_runs() {
    if skip() {
        return;
    }
    use verde::runtime::{from_literal, to_literal, to_literal_i32};
    let rt = Runtime::cpu(default_dir()).unwrap();
    let manifest = rt.manifest().unwrap();
    let art = rt.load("forward.hlo.txt").unwrap();
    // params in manifest order, deterministic init
    let mut lits = Vec::new();
    for (i, (_name, shape)) in manifest.params.iter().enumerate() {
        let t = Tensor::rand(shape.clone(), 1000 + i as u64, 0.05);
        lits.push(to_literal(&t).unwrap());
    }
    let (b, s, v) = (
        manifest.cfg("batch") as usize,
        manifest.cfg("seq") as usize,
        manifest.cfg("vocab") as usize,
    );
    let mut tokens = Tensor::zeros([b, s]);
    for (i, t) in tokens.data_mut().iter_mut().enumerate() {
        *t = ((i * 13) % v) as f32;
    }
    lits.push(to_literal_i32(&tokens).unwrap());
    let outs = art.run(&lits).unwrap();
    assert_eq!(outs.len(), 1);
    let logits = from_literal(&outs[0]).unwrap();
    assert_eq!(logits.shape(), &[b * s, v]);
    assert!(logits.data().iter().all(|x| x.is_finite()));
    // determinism of the whole compiled model
    let outs2 = art.run(&lits).unwrap();
    let logits2 = from_literal(&outs2[0]).unwrap();
    assert!(logits.bit_eq(&logits2));
}
