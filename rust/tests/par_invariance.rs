//! Bitwise invariance of the data-parallel RepOps path (paper §3.2).
//!
//! The worker pool in `util::parallel` farms order-*insensitive* kernel
//! dimensions out to threads; the reproducibility contract demands that
//! the result bits never depend on the thread count. These tests pin that
//! from raw kernels (remainder shapes included — m, n, k deliberately not
//! multiples of the JB/KB blocking) up to trainer checkpoint state roots
//! and final commitments, across thread counts {1, 2, 3, 8}.
//!
//! `set_threads` is process-global, so every test serializes on one lock
//! (poison-safe: an assert failure in one test must not mask the others).

use std::sync::{Mutex, MutexGuard};

use verde::graph::kernels::{run_op, Backend};
use verde::graph::Op;
use verde::model::Preset;
use verde::tensor::{repops, Tensor};
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::util::parallel;
use verde::verde::trainer::TrainerNode;

static THREADS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> MutexGuard<'static, ()> {
    THREADS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

const SWEEP: [usize; 3] = [2, 3, 8];

/// Run `f` at 1 thread for the reference bits, then at every count in
/// `SWEEP`, asserting every output tensor is bitwise identical.
fn assert_bit_invariant(label: &str, f: impl Fn() -> Vec<Tensor>) {
    parallel::set_threads(1);
    let want = f();
    for &t in &SWEEP {
        parallel::set_threads(t);
        let got = f();
        assert_eq!(got.len(), want.len(), "{label}: output arity at {t} threads");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(g.bit_eq(w), "{label}: output {i} bits diverge at {t} threads");
        }
    }
    parallel::set_threads(1);
}

#[test]
fn matmul_family_bitwise_invariant_incl_remainder_shapes() {
    let _g = lock();
    // (m, k, n) chosen so none is a multiple of JB=32 / KB=256, covering
    // the rows path, the panels path (m=1), and serial-threshold shapes.
    for &(m, k, n) in
        &[(33usize, 300usize, 47usize), (7, 64, 130), (1, 257, 96), (65, 31, 33), (130, 129, 131)]
    {
        let a = Tensor::rand([m, k], 42 + m as u64, 1.0);
        let b = Tensor::rand([k, n], 77 + n as u64, 1.0);
        assert_bit_invariant(&format!("matmul({m},{k},{n})"), || {
            vec![repops::matmul(&a, &b)]
        });
        assert_bit_invariant(&format!("matmul_fma({m},{k},{n})"), || {
            vec![repops::matmul_fma(&a, &b)]
        });
    }
    // batch dimension with a remainder vs any thread count in the sweep
    let a = Tensor::rand([5, 21, 67], 7, 1.0);
    let b = Tensor::rand([5, 67, 43], 8, 1.0);
    assert_bit_invariant("bmm(5,21,67,43)", || vec![repops::bmm(&a, &b)]);
}

#[test]
fn reductions_and_norms_bitwise_invariant() {
    let _g = lock();
    // rows * n big enough to actually fan out (EW grain is 16 Ki items)
    let x = Tensor::rand([67, 300], 5, 2.0);
    let gamma = Tensor::rand([300], 6, 1.0);
    let beta = Tensor::rand([300], 7, 1.0);
    assert_bit_invariant("sum_lastdim", || vec![repops::sum_lastdim(&x)]);
    assert_bit_invariant("max_lastdim", || vec![repops::max_lastdim(&x)]);
    assert_bit_invariant("softmax_lastdim", || vec![repops::softmax_lastdim(&x)]);
    assert_bit_invariant("log_softmax_lastdim", || vec![repops::log_softmax_lastdim(&x)]);
    assert_bit_invariant("layernorm", || vec![repops::layernorm(&x, &gamma, &beta, 1e-5)]);
    assert_bit_invariant("rmsnorm", || vec![repops::rmsnorm(&x, &gamma, 1e-6)]);
    // column split: ascending-row accumulation per column must survive
    let tall = Tensor::rand([300, 67], 9, 2.0);
    assert_bit_invariant("sum_axis0", || vec![repops::sum_axis0(&tall)]);
}

#[test]
fn elementwise_and_movement_bitwise_invariant() {
    let _g = lock();
    let x = Tensor::rand([67, 300], 11, 1.0);
    let y = Tensor::rand([67, 300], 12, 1.0);
    let row = Tensor::rand([300], 13, 1.0);
    assert_bit_invariant("add", || vec![repops::add(&x, &y)]);
    assert_bit_invariant("mul", || vec![repops::mul(&x, &y)]);
    assert_bit_invariant("gelu", || vec![repops::gelu(&x)]);
    assert_bit_invariant("scale", || vec![repops::scale(&x, 0.3)]);
    assert_bit_invariant("add_row", || vec![repops::add_row(&x, &row)]);
    assert_bit_invariant("mul_row", || vec![repops::mul_row(&x, &row)]);
    assert_bit_invariant("transpose2d", || vec![repops::transpose2d(&x)]);
    let b3 = Tensor::rand([3, 67, 100], 14, 1.0);
    assert_bit_invariant("transpose_last2", || vec![repops::transpose_last2(&b3)]);
    let table = Tensor::rand([50, 96], 15, 1.0);
    let ids = Tensor::new(
        [400],
        (0..400).map(|i| ((i * 7) % 50) as f32).collect::<Vec<f32>>(),
    );
    assert_bit_invariant("embedding", || vec![repops::embedding(&table, &ids)]);
}

#[test]
fn graph_kernels_bitwise_invariant() {
    let _g = lock();
    // Adam update: the optimizer touches every parameter every step, so
    // its bits feed straight into checkpoint roots.
    let w = Tensor::rand([123, 170], 21, 1.0);
    let grad = Tensor::rand([123, 170], 22, 0.1);
    let m = Tensor::rand([123, 170], 23, 0.01);
    let v = repops::map(&Tensor::rand([123, 170], 24, 0.1), |z| z * z);
    let adam = Op::AdamUpdate { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 };
    assert_bit_invariant("adam_update", || {
        run_op(&adam, &[&w, &grad, &m, &v], Backend::Rep, 3)
    });
    // cross-entropy backward over many rows
    let logits = Tensor::rand([120, 160], 25, 3.0);
    let targets =
        Tensor::new([120], (0..120).map(|i| ((i * 13) % 160) as f32).collect::<Vec<f32>>());
    let dl = Tensor::scalar(1.0);
    assert_bit_invariant("ce_grad", || {
        run_op(&Op::CeGrad, &[&logits, &targets, &dl], Backend::Rep, 1)
    });
    // softmax backward (per-row order-sensitive dot inside parallel rows)
    let sm = repops::softmax_lastdim(&logits);
    let dy = Tensor::rand([120, 160], 26, 1.0);
    assert_bit_invariant("softmax_grad", || {
        run_op(&Op::SoftmaxGrad, &[&sm, &dy], Backend::Rep, 1)
    });
}

#[test]
fn one_training_step_state_root_invariant() {
    let _g = lock();
    let spec = JobSpec::quick(Preset::parse("mlp").unwrap(), 4);
    let session = Session::new(spec);
    parallel::set_threads(1);
    let (s1, loss1) = session.advance(&session.genesis, Backend::Rep);
    let want_root = s1.state_root();
    for &t in &SWEEP {
        parallel::set_threads(t);
        let (st, losst) = session.advance(&session.genesis, Backend::Rep);
        assert_eq!(loss1.to_bits(), losst.to_bits(), "step loss bits at {t} threads");
        assert_eq!(want_root, st.state_root(), "state root diverges at {t} threads");
    }
    parallel::set_threads(1);
}

#[test]
fn full_training_commitment_invariant_across_thread_counts() {
    let _g = lock();
    let spec = JobSpec::quick(Preset::parse("mlp").unwrap(), 6);
    parallel::set_threads(1);
    let want = TrainerNode::honest("t1", spec).train();
    // ≥ 3 distinct thread counts total (1, 2, 3): the acceptance bar for
    // trainer-level checkpoint-root equality.
    for t in [2usize, 3] {
        parallel::set_threads(t);
        let got = TrainerNode::honest(&format!("t{t}"), spec).train();
        assert_eq!(want, got, "final training commitment diverges at {t} threads");
    }
    parallel::set_threads(1);
}
