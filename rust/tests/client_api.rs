//! End-to-end acceptance for the handle-based client API: checkpoint-
//! segment sharding reaching the unsharded verdict across distinct worker
//! subsets, mid-flight cancellation releasing leases to queued jobs,
//! priority scheduling, reproducible-only backend routing, re-admission of
//! transiently slow workers, and the Submit/Status/Cancel wire API served
//! over real TCP sockets.

use std::collections::HashSet;
use std::net::TcpListener;
use std::time::{Duration, Instant};

use verde::graph::kernels::Backend;
use verde::hash::Hash;
use verde::model::Preset;
use verde::net::tcp::{spawn_server, spawn_server_threaded, TcpEndpoint};
use verde::net::Endpoint;
use verde::service::{
    BackendRequirement, Delegation, DelegationFrontend, FaultPlan, JobPolicy, JobRequest,
    JobStatus, PooledWorker, RemoteStatus, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::tensor::profile::HardwareProfile;
use verde::train::checkpoint::split_points;
use verde::train::JobSpec;
use verde::verde::protocol::{Request, Response};
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

fn honest(spec: JobSpec) -> Hash {
    TrainerNode::honest("ref", spec).train()
}

/// The sharding acceptance criterion: a job spanning 4 checkpoint segments
/// is scheduled as independent segments across different worker subsets,
/// every boundary verdict equals the honest checkpoint commitment, and the
/// rolled-up verdict equals the unsharded path's.
#[test]
fn sharded_job_spans_subsets_and_matches_unsharded_verdict() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
        ("w3", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let handle = delegation.submit(JobRequest::new(spec).with_segments(4));
    let outcome = handle.wait();

    assert!(!outcome.cancelled);
    assert_eq!(outcome.segments.len(), 4, "{outcome:?}");
    // Shard edges are the Phase-1 split_points boundaries.
    let ends: Vec<u64> = outcome.segments.iter().map(|s| s.end).collect();
    assert_eq!(ends, split_points(0, 12, 4));
    assert_eq!(outcome.segments[0].start, 0);
    assert_eq!(outcome.segments[3].start, 9);
    // Each boundary verdict is the honest checkpoint commitment there
    // (prefix determinism), and the final one IS the unsharded verdict.
    for s in &outcome.segments {
        assert_eq!(s.accepted, Some(honest(spec.prefix(s.end))), "segment {}", s.seg);
        assert_eq!(s.workers.len(), 2, "k = 2 per segment");
        assert_eq!(s.disputes, 0);
    }
    assert_eq!(outcome.accepted, Some(full), "sharded == unsharded verdict");

    // The first two segments lease concurrently on disjoint subsets (4
    // workers, k = 2): deterministic free-list order makes this exact.
    let s0: HashSet<&String> = outcome.segments[0].workers.iter().collect();
    let s1: HashSet<&String> = outcome.segments[1].workers.iter().collect();
    assert_eq!(outcome.segments[0].workers, vec!["w0", "w1"]);
    assert_eq!(outcome.segments[1].workers, vec!["w2", "w3"]);
    assert!(s0.is_disjoint(&s1), "segments ran on different worker subsets");

    let report = delegation.finish();
    assert_eq!(report.outcomes.len(), 1);
    assert_eq!(pool.idle(), 4, "all leases returned");
}

/// Sharding under fire: a tamperer in the pool is convicted segment by
/// segment and the rolled-up verdict is still the honest one.
#[test]
fn sharded_job_convicts_cheater_and_stays_honest() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Tamper { step: Some(2), delta: 0.05 }),
        ("w3", FaultPlan::Honest),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation.submit(JobRequest::new(spec).with_segments(4)).wait();
    assert_eq!(outcome.accepted, Some(full), "{outcome:?}");
    assert!(outcome.eliminated >= 1, "the tamperer lost at least one segment tournament");
    assert!(outcome.disputes >= 1);
    for s in &outcome.segments {
        assert_eq!(s.accepted, Some(honest(spec.prefix(s.end))), "segment {}", s.seg);
    }
    delegation.finish();
}

/// The cancellation acceptance criterion: cancelling an in-flight job
/// frees its leases and the queued job takes them.
#[test]
fn cancelled_job_frees_leases_and_queued_job_takes_them() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));

    // Job A is long and takes the whole pool; job B queues behind it.
    let slow = JobSpec::quick(Preset::Mlp, 120);
    let mut quick = JobSpec::quick(Preset::Mlp, 3);
    quick.data_seed ^= 0x51C2;
    let want = honest(quick);

    let a = delegation.submit(JobRequest::new(slow));
    let b = delegation.submit(JobRequest::new(quick));
    assert!(a.cancel(), "cancel lands while A is mid-flight");
    assert!(!a.cancel(), "second cancel reports the job already terminal");

    let oa = a.wait();
    assert!(oa.cancelled);
    assert!(oa.accepted.is_none());
    match a.try_status() {
        JobStatus::Done(o) => assert!(o.cancelled),
        other => panic!("{other:?}"),
    }

    // B gets the drained leases (the same two workers, re-entering the
    // pool as A's in-flight Trains settle) and resolves.
    let ob = b.wait();
    assert_eq!(ob.accepted, Some(want), "{ob:?}");
    let mut took = ob.segments[0].workers.clone();
    took.sort();
    assert_eq!(took, vec!["w0", "w1"], "B took A's freed leases");
    assert!(!ob.cancelled);

    let report = delegation.finish();
    assert_eq!(report.outcomes.len(), 2);
    assert_eq!(report.total_cancelled(), 1);
    assert!(report.to_json().contains("\"cancelled\":1"));
    assert!(report.revoked.is_empty(), "cancellation revokes nobody");
    assert_eq!(pool.idle(), 2, "all leases returned");
}

/// Higher-priority jobs lease first when capacity frees up; the
/// deterministic lease sequence number proves the order.
#[test]
fn higher_priority_job_schedules_first() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(1));

    let mk = |seed: u64, steps: u64| {
        let mut spec = JobSpec::quick(Preset::Mlp, steps);
        spec.data_seed ^= seed;
        spec
    };
    let a = delegation.submit(JobRequest::new(mk(1, 30)));
    let low = delegation.submit(JobRequest::new(mk(2, 3)).with_priority(0));
    let high = delegation.submit(JobRequest::new(mk(3, 3)).with_priority(5));

    let (oa, ol, oh) = (a.wait(), low.wait(), high.wait());
    assert!(oa.accepted.is_some());
    assert!(ol.accepted.is_some());
    assert!(oh.accepted.is_some());
    let seq = |o: &verde::service::JobOutcome| o.segments[0].leased_seq;
    assert!(seq(&oa) < seq(&oh), "A leased first (submitted while pool free)");
    assert!(
        seq(&oh) < seq(&ol),
        "priority 5 leased before priority 0 despite later submission: {} vs {}",
        seq(&oh),
        seq(&ol)
    );
    delegation.finish();
}

/// Reproducible-only jobs are routed around free-order hardware, and a
/// requirement nobody can ever satisfy settles unresolved instead of
/// hanging.
#[test]
fn reproducible_only_policy_routes_around_free_backends() {
    let free_hw = Backend::Free(HardwareProfile::T4_16G);
    // The free-order worker sits FIRST in the free list, so default
    // routing would hand it the job; the requirement must skip it.
    let pool = WorkerPool::new(vec![
        PooledWorker::new("gpu0", WorkerHost::new("gpu0", FaultPlan::Honest))
            .with_backend(free_hw),
        PooledWorker::new("rep0", WorkerHost::new("rep0", FaultPlan::Honest)),
    ]);
    let spec = JobSpec::quick(Preset::Mlp, 4);
    let delegation = Delegation::start(&pool, ServiceConfig::new(1));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_backend(BackendRequirement::ReproducibleOnly))
        .wait();
    assert_eq!(outcome.accepted, Some(honest(spec)));
    assert_eq!(outcome.segments[0].workers, vec!["rep0"], "free-order worker skipped");

    // An `Any` job may use either; with rep0 the only other worker, the
    // front of the free list (gpu0) serves it.
    let any = delegation.submit(JobRequest::new(spec)).wait();
    assert!(any.accepted.is_some());
    delegation.finish();

    // A pool with no reproducible worker can never satisfy the
    // requirement: the job settles unresolved promptly, no hang.
    let all_free = WorkerPool::new(vec![PooledWorker::new(
        "gpu0",
        WorkerHost::new("gpu0", FaultPlan::Honest),
    )
    .with_backend(free_hw)]);
    let delegation = Delegation::start(&all_free, ServiceConfig::new(1));
    let t0 = Instant::now();
    let outcome = delegation
        .submit(JobRequest::new(spec).with_backend(BackendRequirement::ReproducibleOnly))
        .wait();
    assert!(outcome.accepted.is_none());
    assert!(!outcome.cancelled);
    assert!(t0.elapsed() < Duration::from_secs(30), "must fail fast, not hang");
    delegation.finish();
}

/// The re-admission satellite: a transiently slow worker misses its
/// dispatch deadline, is suspended with backoff instead of permanently
/// expelled, answers its parole ping once recovered, and re-enters the
/// pool to serve later jobs.
#[test]
fn napping_worker_is_suspended_then_readmitted() {
    let pool = in_process_pool(&[
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Nap { at_request: 1, nap_ms: 1200 }),
    ]);
    let mut cfg = ServiceConfig::new(2);
    cfg.dispatch_deadline = Duration::from_millis(300);
    cfg.readmit_backoff = Some(Duration::from_millis(200));
    cfg.ping_deadline = Duration::from_secs(10);
    cfg.max_strikes = 5;
    let delegation = Delegation::start(&pool, cfg);

    let spec = JobSpec::quick(Preset::Mlp, 4);
    let o1 = delegation.submit(JobRequest::new(spec)).wait();
    assert_eq!(o1.accepted, Some(honest(spec)), "{o1:?}");
    assert_eq!(o1.requeues, 1, "the nap cost one re-queue");
    assert_eq!(o1.revoked, 1, "the napping lease was suspended");

    // Once the nap ends, the parole ping finds w1 healthy again. (No
    // assertion on the intermediate suspended state: under parallel test
    // load the re-admission may already have happened by now.)
    let t0 = Instant::now();
    while pool.size() < 2 {
        assert!(t0.elapsed() < Duration::from_secs(20), "w1 was never re-admitted");
        std::thread::sleep(Duration::from_millis(50));
    }
    assert_eq!(pool.suspended(), 0);

    // The re-admitted worker serves the next job like anyone else.
    let mut spec2 = spec;
    spec2.data_seed ^= 0xBEEF;
    let o2 = delegation.submit(JobRequest::new(spec2)).wait();
    assert_eq!(o2.accepted, Some(honest(spec2)));
    assert_eq!(o2.revoked, 0, "no more misses after recovery");

    let report = delegation.finish();
    assert_eq!(report.revoked, vec!["w1".to_string()], "one suspension on the record");
    assert_eq!(pool.size(), 2);
}

/// The threaded-accept satellite: ≥ 4 remote TCP clients drive one
/// coordinator frontend **simultaneously** (each connection served on its
/// own thread against a clone sharing the handle registry). Every client
/// submits and polls its own jobs to the honest verdict, and a final
/// connection proves cross-connection visibility: it can `Status` every
/// job id the other clients created.
#[test]
fn four_concurrent_tcp_clients_submit_simultaneously() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(1));
    let frontend = DelegationFrontend::new("coordinator", delegation.client());

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    const CLIENTS: usize = 4;
    const JOBS_PER_CLIENT: u64 = 2;
    // 4 concurrent client connections + 1 final cross-visibility probe.
    let server = spawn_server_threaded(listener, frontend.clone(), Some(CLIENTS + 1));

    let client_threads: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut ep =
                    TcpEndpoint::connect(&format!("client-{c}"), addr).expect("connect frontend");
                let mut submitted: Vec<(u64, Hash)> = Vec::new();
                for j in 0..JOBS_PER_CLIENT {
                    let mut spec = JobSpec::quick(Preset::Mlp, 3);
                    spec.data_seed ^= ((c as u64) << 32) | j;
                    let want = honest(spec);
                    match ep.call(Request::Submit { spec, policy: JobPolicy::default() }) {
                        Response::Submitted { job_id } => submitted.push((job_id, want)),
                        other => panic!("client {c}: {other:?}"),
                    }
                }
                // Poll every submitted job to completion over this same
                // connection (other clients are polling concurrently).
                let t0 = Instant::now();
                let mut done = vec![false; submitted.len()];
                while !done.iter().all(|&d| d) {
                    assert!(
                        t0.elapsed() < Duration::from_secs(120),
                        "client {c}: jobs never finished"
                    );
                    for (i, &(job_id, want)) in submitted.iter().enumerate() {
                        if done[i] {
                            continue;
                        }
                        match ep.call(Request::Status { job_id }) {
                            Response::Status(RemoteStatus::Done { accepted, cancelled, .. }) => {
                                assert!(!cancelled);
                                assert_eq!(accepted, Some(want), "client {c} job {job_id}");
                                done[i] = true;
                            }
                            Response::Status(_) => {}
                            other => panic!("client {c}: {other:?}"),
                        }
                    }
                    std::thread::sleep(Duration::from_millis(10));
                }
                let ids: Vec<u64> = submitted.into_iter().map(|(id, _)| id).collect();
                ids
            })
        })
        .collect();
    let mut all_ids: Vec<u64> = Vec::new();
    for t in client_threads {
        all_ids.extend(t.join().expect("client thread"));
    }
    all_ids.sort_unstable();
    let expect: Vec<u64> = (0..(CLIENTS as u64 * JOBS_PER_CLIENT)).collect();
    assert_eq!(all_ids, expect, "every submission got a distinct global id");

    // Cross-connection visibility: a fresh client sees all of them Done.
    let mut probe = TcpEndpoint::connect("probe", addr).expect("connect probe");
    for id in all_ids {
        match probe.call(Request::Status { job_id: id }) {
            Response::Status(RemoteStatus::Done { accepted, .. }) => {
                assert!(accepted.is_some(), "job {id}");
            }
            other => panic!("probe: {other:?}"),
        }
    }
    drop(probe);
    server.join().expect("threaded frontend server");
    let report = delegation.finish();
    assert_eq!(report.outcomes.len(), CLIENTS * JOBS_PER_CLIENT as usize);
    assert_eq!(pool.idle(), 2, "all leases returned");
}

/// Regression (frontend retirement dormancy): terminal jobs must migrate
/// into the bounded finished set on `Status`/`Cancel` traffic too — a
/// frontend that never sees another Submit must not pin every terminal
/// `JobOutcome` in its live map forever.
#[test]
fn frontend_retires_terminal_jobs_without_a_trailing_submit() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let mut frontend = DelegationFrontend::new("coordinator", delegation.client());

    let mk = |seed: u64| {
        let mut spec = JobSpec::quick(Preset::Mlp, 3);
        spec.data_seed ^= seed;
        spec
    };
    for seed in [1u64, 2] {
        match frontend.call(Request::Submit { spec: mk(seed), policy: JobPolicy::default() }) {
            Response::Submitted { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    // Drain to terminal with NO further frontend traffic.
    for h in frontend.handles() {
        h.wait();
    }

    // One Status call — not a Submit — must retire both terminal jobs into
    // the finished set.
    match frontend.call(Request::Status { job_id: 0 }) {
        Response::Status(RemoteStatus::Done { accepted, .. }) => assert!(accepted.is_some()),
        other => panic!("{other:?}"),
    }
    assert_eq!(frontend.tracked(), (0, 2), "terminal jobs still pinned in the live map");

    // The Cancel path retires too, and a terminal job cancels false.
    match frontend.call(Request::Cancel { job_id: 1 }) {
        Response::Cancelled(landed) => assert!(!landed, "job 1 was already terminal"),
        other => panic!("{other:?}"),
    }
    assert_eq!(frontend.tracked(), (0, 2));
    delegation.finish();
}

/// Regression (evicted-handle consistency): ids FIFO-evicted past the
/// 1024-handle retention cap answer `Status → Unknown` and
/// `Cancel → false` deterministically — never a hang, never a panic.
#[test]
fn evicted_ids_answer_unknown_and_cancel_false_past_retention_cap() {
    const CAP: usize = 1024; // MAX_FINISHED_RETAINED
    const OVERFLOW: usize = 6;
    let pool = in_process_pool(&[("w0", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(1));
    let mut frontend = DelegationFrontend::new("coordinator", delegation.client());

    // Zero-step jobs settle without touching a worker, so overflowing the
    // retained set is cheap.
    let spec = JobSpec::quick(Preset::Mlp, 0);
    for i in 0..(CAP + OVERFLOW) as u64 {
        match frontend.call(Request::Submit { spec, policy: JobPolicy::default() }) {
            Response::Submitted { job_id } => assert_eq!(job_id, i),
            other => panic!("{other:?}"),
        }
    }
    for h in frontend.handles() {
        h.wait();
    }

    // One sweep retires everything terminal; the oldest OVERFLOW ids fall
    // off the FIFO (retirement is lowest-id-first, so eviction is exact).
    match frontend.call(Request::Status { job_id: (CAP + OVERFLOW) as u64 - 1 }) {
        Response::Status(RemoteStatus::Done { .. }) => {}
        other => panic!("{other:?}"),
    }
    assert_eq!(frontend.tracked(), (0, CAP), "retention cap not enforced");

    for id in 0..OVERFLOW as u64 {
        assert!(
            matches!(
                frontend.call(Request::Status { job_id: id }),
                Response::Status(RemoteStatus::Unknown)
            ),
            "evicted id {id} did not answer Unknown"
        );
        assert!(
            matches!(frontend.call(Request::Cancel { job_id: id }), Response::Cancelled(false)),
            "evicted id {id} did not cancel false"
        );
    }
    // Survivors still answer Done.
    assert!(matches!(
        frontend.call(Request::Status { job_id: OVERFLOW as u64 }),
        Response::Status(RemoteStatus::Done { .. })
    ));
    delegation.finish();
}

/// The wire API end to end: a remote client submits (sharded), polls
/// status to completion, probes an unknown id, and cancels a long job —
/// all over a real TCP socket against a `DelegationFrontend`.
#[test]
fn remote_client_submits_polls_and_cancels_over_tcp() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let frontend = DelegationFrontend::new("coordinator", delegation.client());

    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let server = spawn_server(listener, frontend, Some(1));
    let mut ep = TcpEndpoint::connect("coordinator", addr).expect("connect frontend");

    // Submit a sharded job and poll it to completion.
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let want = honest(spec);
    let policy = JobPolicy { segments: 2, ..JobPolicy::default() };
    let job_id = match ep.call(Request::Submit { spec, policy }) {
        Response::Submitted { job_id } => job_id,
        other => panic!("{other:?}"),
    };
    let t0 = Instant::now();
    let done = loop {
        assert!(t0.elapsed() < Duration::from_secs(120), "remote job never finished");
        match ep.call(Request::Status { job_id }) {
            Response::Status(RemoteStatus::Done { accepted, cancelled, .. }) => {
                break (accepted, cancelled)
            }
            Response::Status(RemoteStatus::Queued)
            | Response::Status(RemoteStatus::Running { .. }) => {
                std::thread::sleep(Duration::from_millis(20));
            }
            other => panic!("{other:?}"),
        }
    };
    assert_eq!(done, (Some(want), false), "remote sharded job reaches the honest verdict");

    // Unknown ids answer Unknown, not an error.
    assert!(matches!(
        ep.call(Request::Status { job_id: 9999 }),
        Response::Status(RemoteStatus::Unknown)
    ));
    // Non-API protocol requests are refused by the frontend.
    assert!(matches!(ep.call(Request::FinalCommit), Response::Refuse(_)));

    // Submit a long job and cancel it mid-flight over the wire.
    let mut slow = spec;
    slow.steps = 120;
    slow.data_seed ^= 0xAB;
    let slow_id = match ep.call(Request::Submit { spec: slow, policy: JobPolicy::default() }) {
        Response::Submitted { job_id } => job_id,
        other => panic!("{other:?}"),
    };
    match ep.call(Request::Cancel { job_id: slow_id }) {
        Response::Cancelled(ok) => assert!(ok, "cancel lands mid-flight"),
        other => panic!("{other:?}"),
    }
    let t0 = Instant::now();
    loop {
        assert!(t0.elapsed() < Duration::from_secs(60), "cancelled job never settled");
        match ep.call(Request::Status { job_id: slow_id }) {
            Response::Status(RemoteStatus::Done { cancelled, accepted, .. }) => {
                assert!(cancelled);
                assert!(accepted.is_none());
                break;
            }
            Response::Status(_) => std::thread::sleep(Duration::from_millis(20)),
            other => panic!("{other:?}"),
        }
    }
    // Cancelling an unknown id is a clean false.
    assert!(matches!(ep.call(Request::Cancel { job_id: 4242 }), Response::Cancelled(false)));

    drop(ep); // sends Shutdown: the serve loop ends and hands the frontend back
    server.join().expect("frontend server thread");
    let report = delegation.finish();
    assert_eq!(report.outcomes.len(), 2);
    assert_eq!(report.total_cancelled(), 1);
    assert_eq!(pool.idle(), 2, "all leases returned after remote cancel");
}
