//! The cross-hardware divergence satellite: a worker that *actually
//! computes* with free-order (non-reproducible) kernels while advertising
//! a RepOps backend sneaks into a reproducible-only tournament — its
//! commitment diverges bitwise, the dispute narrows to a compute node, and
//! the referee's single-operator RepOps recomputation convicts it.

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::service::{
    BackendRequirement, Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig,
    WorkerHost, WorkerPool,
};
use verde::tensor::profile::HardwareProfile;
use verde::train::JobSpec;
use verde::verde::faults::Fault;
use verde::verde::referee::DecisionCase;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

/// The dispute-level ground truth: an honest-intent trainer on free-order
/// hardware diverges from RepOps and is the convicted party, via operator
/// recomputation (not a refusal/technicality).
#[test]
fn free_order_trainer_convicted_by_repops_recomputation() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let mut honest = TrainerNode::honest("honest", spec);
    let mut free = TrainerNode::new(
        "free",
        spec,
        Backend::Free(HardwareProfile::T4_16G),
        Fault::NonRepHardware,
    );
    honest.train();
    free.train();
    let r = run_dispute(spec, honest, free);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(
        r.verdict.case(),
        Some(DecisionCase::OutputRecompute),
        "cross-hardware divergence is pinned to a single operator: {:?}",
        r.verdict
    );
    assert!(r.diverging_step.is_some());
}

/// End to end through the service: the free-order worker lies about its
/// backend (advertises RepOps), so reproducible-only routing cannot screen
/// it out — but the tournament convicts it on its first job, the honest
/// worker's claim is accepted, and later jobs keep resolving.
#[test]
fn lying_free_order_worker_is_convicted_in_rep_only_tournament() {
    // The WorkerHost really computes with free-order kernels; the
    // PooledWorker wrapper advertises the default (Rep) backend — the lie.
    let liar = WorkerHost::new("liar", FaultPlan::Honest)
        .with_backend(Backend::Free(HardwareProfile::A100_40G));
    let pool = WorkerPool::new(vec![
        PooledWorker::new("liar", liar),
        PooledWorker::new("rep0", WorkerHost::new("rep0", FaultPlan::Honest)),
    ]);

    let spec = JobSpec::quick(Preset::Mlp, 6);
    let want = TrainerNode::honest("ref", spec).train();
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let outcome = delegation
        .submit(JobRequest::new(spec).with_backend(BackendRequirement::ReproducibleOnly))
        .wait();

    assert_eq!(outcome.accepted, Some(want), "honest claim wins: {outcome:?}");
    assert_eq!(outcome.winner.as_deref(), Some("rep0"));
    assert_eq!(outcome.disputes, 1, "one pairwise dispute resolves the divergence");
    assert_eq!(outcome.eliminated, 1, "the free-order liar is convicted");

    // A second job: the liar is eliminated per-tournament, not expelled
    // from the pool (backend lies are economic failures, not liveness
    // ones) — it loses again, the verdict stays honest.
    let mut spec2 = spec;
    spec2.data_seed ^= 0xF00D;
    let want2 = TrainerNode::honest("ref2", spec2).train();
    let o2 = delegation
        .submit(JobRequest::new(spec2).with_backend(BackendRequirement::ReproducibleOnly))
        .wait();
    assert_eq!(o2.accepted, Some(want2));
    assert_eq!(o2.eliminated, 1);

    let report = delegation.finish();
    assert_eq!(report.total_eliminated(), 2);
    assert!(report.revoked.is_empty(), "convictions are not revocations");
    assert_eq!(pool.idle(), 2);
}
