//! End-to-end service acceptance: a coordinator drives many jobs through a
//! 4-worker pool over **real TCP sockets on localhost** — every run
//! includes faulty workers, every job must resolve to the honest
//! commitment, and the bytes measured on the wire must match the
//! protocol's `wire_size()` accounting exactly.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use verde::graph::kernels::Backend;
use verde::hash::Hash;
use verde::model::Preset;
use verde::net::mux::Mux;
use verde::net::tcp::{spawn_server, TcpEndpoint};
use verde::net::{Endpoint, Metered};
use verde::service::{
    run_service, run_service_with, FaultPlan, PooledWorker, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::train::JobSpec;
use verde::verde::faults::Fault;
use verde::verde::protocol::Request;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;
use verde::verde::wire::FRAME_HEADER_LEN;

fn ephemeral() -> TcpListener {
    TcpListener::bind("127.0.0.1:0").expect("bind ephemeral port")
}

fn expected_honest(spec: JobSpec) -> Hash {
    TrainerNode::honest("ref", spec).train()
}

/// ≥ 8 jobs through the coordinator against a 4-worker TCP pool — two
/// honest workers, two with distinct faults, so every job's run contains
/// faulty participants. Every job must reach the honest verdict.
#[test]
fn eight_plus_jobs_against_four_tcp_workers_reach_honest_verdicts() {
    let plans = [
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Tamper { step: Some(2), delta: 0.05 }),
        ("w3", FaultPlan::WrongData { step: Some(3) }),
    ];

    // one worker "process" (server thread) per plan, on its own socket
    let mut servers = Vec::new();
    let mut endpoints = Vec::new();
    for (name, plan) in plans {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        servers.push(spawn_server(listener, WorkerHost::new(name, plan), Some(1)));
        endpoints.push((name, TcpEndpoint::connect(name, addr).expect("connect worker")));
    }
    let pool = WorkerPool::new(
        endpoints.into_iter().map(|(name, ep)| PooledWorker::new(name, ep)).collect(),
    );

    // 9 distinct jobs (per-job data stream)
    let jobs: Vec<JobSpec> = (0..9u64)
        .map(|i| {
            let mut spec = JobSpec::quick(Preset::Mlp, 5);
            spec.data_seed = spec.data_seed.wrapping_add(i * 7919);
            spec
        })
        .collect();
    let expected: Vec<Hash> = jobs.iter().map(|s| expected_honest(*s)).collect();

    let report = run_service(jobs, &pool, 4);

    assert_eq!(report.outcomes.len(), 9);
    for o in &report.outcomes {
        let want = expected[o.job_id as usize];
        assert_eq!(
            o.accepted,
            Some(want),
            "job {} must accept the honest commitment",
            o.job_id
        );
        let winner = o.winner.as_deref().expect("resolved job has a winner");
        assert!(winner == "w0" || winner == "w1", "honest worker wins, got {winner}");
        // 3 distinct claims (h, tamper, wrong-data) → exactly 2 disputes,
        // both cheaters eliminated.
        assert_eq!(o.disputes, 2, "job {}", o.job_id);
        assert_eq!(o.eliminated, 2, "job {}", o.job_id);
        assert!(o.bytes > 0, "byte accounting recorded");
    }
    assert_eq!(report.total_disputes(), 18);
    assert!(report.jobs_per_sec() > 0.0);

    // orderly shutdown: workers get Shutdown, server threads hand their
    // hosts back with 9 jobs trained each (every job visited all 4).
    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for server in servers {
        let host = server.join().expect("worker thread");
        assert_eq!(host.counters.get("jobs_trained"), 9, "{}", host.name());
    }
}

/// The acceptance criterion on communication accounting: for a dispute run
/// over real sockets, raw bytes on the wire equal the protocol's
/// `wire_size()` sums plus exactly one 4-byte frame prefix per message —
/// nothing modeled, nothing hidden.
#[test]
fn tcp_dispute_bytes_match_wire_size_accounting_exactly() {
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        Fault::TamperOutput { step: 3, node: 7, delta: 0.5 },
    );
    honest.train();
    cheat.train();

    // each trainer behind its own socket
    let l0 = ephemeral();
    let l1 = ephemeral();
    let (a0, a1) = (l0.local_addr().unwrap(), l1.local_addr().unwrap());
    let s0 = spawn_server(l0, honest, Some(1));
    let s1 = spawn_server(l1, cheat, Some(1));

    let t0 = TcpEndpoint::connect("honest", a0).unwrap();
    let t1 = TcpEndpoint::connect("cheat", a1).unwrap();
    let mut m0 = Metered::new(t0);
    let mut m1 = Metered::new(t1);

    let r = run_dispute(spec, &mut m0, &mut m1);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);

    for (who, m) in [("honest", &m0), ("cheat", &m1)] {
        let frames = m.counters.get("requests");
        let header = FRAME_HEADER_LEN as u64;
        assert!(frames > 0, "{who}: dispute exchanged messages");
        // requests: raw socket bytes == Σ wire_size + one tagged frame
        // header (u32 length + u64 correlation tag) per message
        assert_eq!(
            m.inner.raw_sent(),
            m.bytes_sent() + header * frames,
            "{who}: request bytes on the wire must match wire_size() exactly"
        );
        // responses: one frame per request
        assert_eq!(
            m.inner.raw_received(),
            m.bytes_received() + header * frames,
            "{who}: response bytes on the wire must match wire_size() exactly"
        );
        // and the socket endpoint's own payload counters agree too
        assert_eq!(m.inner.counters.get("bytes_to"), m.bytes_sent(), "{who}");
        assert_eq!(m.inner.counters.get("bytes_from"), m.bytes_received(), "{who}");
    }
    // the dispute report's byte accounting is the same measurement
    assert_eq!(r.bytes[0], m0.bytes_sent() + m0.bytes_received());
    assert_eq!(r.bytes[1], m1.bytes_sent() + m1.bytes_received());

    drop(m0);
    drop(m1);
    s0.join().unwrap();
    s1.join().unwrap();
}

/// Concurrency shape: with k=2 against 4 workers, two scheduler lanes run
/// jobs in parallel; pairs that happen to be all-honest agree without a
/// dispute, pairs containing the cheater convict it — and in all cases the
/// accepted commitment is honest.
#[test]
fn k2_lanes_share_the_pool_and_still_reach_honest_verdicts() {
    let plans = [
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Honest),
        ("w3", FaultPlan::SkipSteps { after: Some(2) }),
    ];
    let mut servers = Vec::new();
    let mut workers = Vec::new();
    for (name, plan) in plans {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        servers.push(spawn_server(listener, WorkerHost::new(name, plan), Some(1)));
        workers.push(PooledWorker::new(name, TcpEndpoint::connect(name, addr).unwrap()));
    }
    let pool = WorkerPool::new(workers);

    let jobs: Vec<JobSpec> = (0..8u64)
        .map(|i| {
            let mut spec = JobSpec::quick(Preset::Mlp, 4);
            spec.data_seed = spec.data_seed.wrapping_add(i * 104_729);
            spec
        })
        .collect();
    let expected: Vec<Hash> = jobs.iter().map(|s| expected_honest(*s)).collect();

    let report = run_service(jobs, &pool, 2);
    assert_eq!(report.outcomes.len(), 8);
    for o in &report.outcomes {
        assert_eq!(o.accepted, Some(expected[o.job_id as usize]), "job {}", o.job_id);
        assert!(o.disputes <= 1);
    }

    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for server in servers {
        server.join().unwrap();
    }
}

/// The event-core acceptance scenario: one of k = 4 TCP workers stalls
/// mid-job (it never answers its `Train` dispatch). The per-request
/// deadline fires, the worker's lease is revoked, the job re-queues onto
/// the three survivors, and every job still reaches the honest verdict —
/// all over **multiplexed** sockets with zero coordinator threads per
/// worker, and without any thread left blocked on the dead socket.
#[test]
fn stalled_tcp_worker_is_revoked_and_job_requeues_to_honest_verdict() {
    let plans = [
        ("w0", FaultPlan::Honest),
        ("w1", FaultPlan::Honest),
        ("w2", FaultPlan::Tamper { step: Some(2), delta: 0.05 }),
        ("w3", FaultPlan::Stall { at_request: 1 }),
    ];
    let mux = Mux::new();
    let mut servers = Vec::new();
    let mut workers = Vec::new();
    for (name, plan) in plans {
        let listener = ephemeral();
        let addr = listener.local_addr().unwrap();
        servers.push((name, spawn_server(listener, WorkerHost::new(name, plan), Some(1))));
        let conn = mux.connect(name, addr).expect("connect worker");
        workers.push(PooledWorker::mux(name, conn));
    }
    let pool = WorkerPool::new(workers);

    let jobs: Vec<JobSpec> = (0..2u64)
        .map(|i| {
            let mut spec = JobSpec::quick(Preset::Mlp, 4);
            spec.data_seed = spec.data_seed.wrapping_add(i * 6151);
            spec
        })
        .collect();
    let expected: Vec<Hash> = jobs.iter().map(|s| expected_honest(*s)).collect();

    let mut cfg = ServiceConfig::new(4);
    cfg.dispatch_deadline = Duration::from_secs(3);
    let t0 = Instant::now();
    let report = run_service_with(jobs, &pool, cfg);

    assert_eq!(report.outcomes.len(), 2);
    for o in &report.outcomes {
        assert_eq!(
            o.accepted,
            Some(expected[o.job_id as usize]),
            "job {} must still reach the honest verdict",
            o.job_id
        );
        let winner = o.winner.as_deref().expect("resolved");
        assert!(winner == "w0" || winner == "w1", "honest worker wins, got {winner}");
    }
    // job 0 hit the staller (k=4 takes the whole pool), paid the deadline
    // and exactly one re-queue; after revocation the pool is 3 wide and
    // job 1 sails through.
    assert_eq!(report.outcomes[0].requeues, 1, "{:?}", report.outcomes[0]);
    assert_eq!(report.outcomes[0].revoked, 1);
    assert_eq!(report.outcomes[1].requeues, 0);
    assert_eq!(report.revoked, vec!["w3".to_string()]);
    assert_eq!(pool.size(), 3, "revoked worker left the pool");
    assert_eq!(pool.idle(), 3, "surviving leases all returned");
    assert_eq!(report.total_requeued(), 1);
    // The whole run must finish promptly after the one deadline — nothing
    // may sit blocked on the dead socket.
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "run took {:?}: something blocked on the stalled worker",
        t0.elapsed()
    );
    let json = report.to_json();
    assert!(json.contains("\"requeued\":1"), "{json}");
    assert!(json.contains("\"revoked\":1"), "{json}");

    // Orderly shutdown of the three survivors over the mux; their server
    // threads hand their hosts back. The stalled worker's serve thread is
    // stranded inside its own sleep — by design we never join it, proving
    // no coordinator-side resource is tied to the dead peer.
    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    for (name, server) in servers {
        if name != "w3" {
            let host = server.join().expect("surviving worker thread");
            assert!(host.counters.get("jobs_trained") >= 1, "{name}");
        }
    }
    drop(mux);
}
