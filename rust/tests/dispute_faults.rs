//! Integration: one full dispute per fault class, asserting the referee
//! convicts exactly the dishonest trainer through the expected decision
//! case (the DESIGN.md §1 fault table, executed).

use verde::graph::kernels::Backend;
use verde::graph::Op;
use verde::model::Preset;
use verde::tensor::profile::HardwareProfile;
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::verde::faults::{first_mutable_node, Fault};
use verde::verde::referee::{DecisionCase, Verdict};
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn dispute_with(spec: JobSpec, fault: Fault, cheater_backend: Backend) -> verde::verde::DisputeReport {
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new("cheat", spec, cheater_backend, fault);
    honest.train();
    cheat.train();
    run_dispute(spec, honest, cheat)
}

fn assert_convicts(spec: JobSpec, fault: Fault, case: DecisionCase) {
    let r = dispute_with(spec, fault, Backend::Rep);
    assert_eq!(
        r.verdict.convicted(),
        Some(1),
        "{fault:?} verdict: {:?}",
        r.verdict
    );
    assert_eq!(r.verdict.case(), Some(case), "{fault:?}");
    if let Some(expected) = fault.first_divergent_step() {
        assert_eq!(r.diverging_step, Some(expected), "{fault:?}");
    }
}

#[test]
fn tamper_output_case3() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    // node 8 is the ReLU output in the MLP extended graph
    assert_convicts(
        spec,
        Fault::TamperOutput { step: 5, node: 8, delta: 5.0 },
        DecisionCase::OutputRecompute,
    );
}

#[test]
fn tamper_update_node_case3() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let upd = {
        let s = Session::new(spec);
        *s.program.param_updates.values().map(|sl| &sl.node).min().unwrap()
    };
    assert_convicts(
        spec,
        Fault::TamperOutput { step: 4, node: upd, delta: 0.01 },
        DecisionCase::OutputRecompute,
    );
}

#[test]
fn wrong_operator_case1() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let node = {
        let s = Session::new(spec);
        first_mutable_node(&s.program.graph).expect("mlp has a mutable op")
    };
    assert_convicts(
        spec,
        Fault::WrongOperator { step: 3, node },
        DecisionCase::Structure,
    );
}

#[test]
fn wrong_data_case2a_data() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    assert_convicts(spec, Fault::WrongData { step: 6 }, DecisionCase::DataCheck);
}

#[test]
fn skip_optimizer_case3() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    assert_convicts(
        spec,
        Fault::SkipOptimizer { step: 5 },
        DecisionCase::OutputRecompute,
    );
}

#[test]
fn skip_steps_lazy_trainer() {
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let r = dispute_with(spec, Fault::SkipSteps { after: 7 }, Backend::Rep);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(r.diverging_step, Some(8));
    // the lazy trainer replays a stale trace whose data node contradicts
    // the committed dataset for step 8
    assert_eq!(r.verdict.case(), Some(DecisionCase::DataCheck));
}

#[test]
fn forged_lineage_case2b() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let mm = {
        let s = Session::new(spec);
        s.program.graph.nodes.iter().position(|n| matches!(n.op, Op::MatMul)).unwrap()
    };
    assert_convicts(
        spec,
        Fault::ForgedLineage { step: 4, node: mm },
        DecisionCase::InputLineage,
    );
}

#[test]
fn inconsistent_commit_line7() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let r = dispute_with(spec, Fault::InconsistentCommit { step: 5 }, Backend::Rep);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(r.verdict.case(), Some(DecisionCase::CommitInconsistent));
}

#[test]
fn non_rep_hardware_convicted_by_recompute() {
    // honest *intent*, free-order kernels: the referee's RepOps
    // recomputation sides with the reproducible trainer — the §3 motivation
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let r = dispute_with(
        spec,
        Fault::NonRepHardware,
        Backend::Free(HardwareProfile::T4_16G),
    );
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(r.verdict.case(), Some(DecisionCase::OutputRecompute));
}

#[test]
fn two_free_order_trainers_on_different_gpus_both_lose() {
    // The paper's nightmare scenario without RepOps: two honest trainers on
    // different hardware disagree, and the referee (on RepOps) refutes both.
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let mut t4 = TrainerNode::new(
        "t4",
        spec,
        Backend::Free(HardwareProfile::T4_16G),
        Fault::NonRepHardware,
    );
    let mut a100 = TrainerNode::new(
        "a100",
        spec,
        Backend::Free(HardwareProfile::A100_40G),
        Fault::NonRepHardware,
    );
    t4.train();
    a100.train();
    let r = run_dispute(spec, t4, a100);
    match r.verdict {
        Verdict::BothDishonest { case, .. } => {
            assert_eq!(case, DecisionCase::OutputRecompute)
        }
        // depending on where rounding falls, one trainer may happen to match
        // RepOps on the single disputed node; then only the other is caught
        Verdict::Dishonest { case, .. } => assert_eq!(case, DecisionCase::OutputRecompute),
        other => panic!("expected conviction(s), got {other:?}"),
    }
}

#[test]
fn transformer_model_dispute() {
    // the full pipeline on a real transformer graph (llama-tiny)
    let spec = JobSpec::quick(Preset::LlamaTiny, 6);
    let upd = {
        let s = Session::new(spec);
        *s.program.param_updates.values().map(|sl| &sl.node).min().unwrap()
    };
    let r = dispute_with(
        spec,
        Fault::TamperOutput { step: 4, node: upd, delta: 0.02 },
        Backend::Rep,
    );
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(r.verdict.case(), Some(DecisionCase::OutputRecompute));
    assert_eq!(r.diverging_step, Some(4));
    assert_eq!(r.referee.get("ops_recomputed"), 1);
}

#[test]
fn bert_model_dispute() {
    let spec = JobSpec::quick(Preset::BertTiny, 5);
    let r = dispute_with(spec, Fault::WrongData { step: 2 }, Backend::Rep);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
    assert_eq!(r.verdict.case(), Some(DecisionCase::DataCheck));
}

#[test]
fn referee_work_is_small() {
    // §2.2's point: the referee recomputes ONE operator and moves KBs, not
    // the GBs of a full training step / checkpoint.
    let spec = JobSpec::quick(Preset::LlamaTiny, 8);
    let upd = {
        let s = Session::new(spec);
        *s.program.param_updates.values().map(|sl| &sl.node).min().unwrap()
    };
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        Fault::TamperOutput { step: 6, node: upd, delta: 0.02 },
    );
    honest.train();
    cheat.train();
    let state_bytes = honest.session.genesis.byte_len() as u64;
    let r = run_dispute(spec, honest, cheat);
    assert_eq!(r.verdict.convicted(), Some(1));
    assert_eq!(r.referee.get("ops_recomputed"), 1);
    let moved = r.bytes[0] + r.bytes[1];
    assert!(
        moved < state_bytes,
        "dispute moved {moved} bytes vs state {state_bytes}"
    );
}

#[test]
fn threaded_trainers_resolve_disputes() {
    // trainers as independent actor threads (the deployment topology)
    let spec = JobSpec::quick(Preset::Mlp, 6);
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        Fault::WrongData { step: 3 },
    );
    honest.train();
    cheat.train();
    let h = verde::net::threaded::spawn(honest);
    let c = verde::net::threaded::spawn(cheat);
    let r = run_dispute(spec, h, c);
    assert_eq!(r.verdict.convicted(), Some(1), "{:?}", r.verdict);
}
