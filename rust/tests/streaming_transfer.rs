//! Acceptance battery for the streaming state-transfer pipeline: repeat
//! jobs seed from the content-addressed checkpoint cache with zero
//! re-fetch, an oversize certified manifest is refused (reported, not
//! truncated) and the successor falls back to prefix re-training, and the
//! epoll and scan readiness backends certify bit-identical verdicts and
//! state roots for the same streamed job.

use std::net::TcpListener;

use verde::hash::Hash;
use verde::model::Preset;
use verde::net::mux::Mux;
use verde::net::readiness::{BackendKind, Readiness};
use verde::net::tcp::spawn_server;
use verde::net::Endpoint;
use verde::service::{
    Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::train::JobSpec;
use verde::verde::protocol::Request;
use verde::verde::trainer::TrainerNode;

fn in_process_pool(plans: &[(&str, FaultPlan)]) -> WorkerPool {
    WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    )
}

fn honest(spec: JobSpec) -> Hash {
    TrainerNode::honest("ref", spec).train()
}

/// A re-submitted job's seeds come straight from the checkpoint cache:
/// the certified roots are content-addressed, so the second job pays zero
/// chunk fetches, and both the report and the registry see exactly the
/// same hit/miss totals.
#[test]
fn repeat_job_seeds_from_checkpoint_cache() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let spec = JobSpec::quick(Preset::Mlp, 9);
    let full = honest(spec);

    let delegation = Delegation::start(&pool, ServiceConfig::new(2));
    let registry = delegation.registry().clone();
    let first = delegation
        .submit(JobRequest::new(spec).with_segments(3).with_state_transfer())
        .wait();
    let second = delegation
        .submit(JobRequest::new(spec).with_segments(3).with_state_transfer())
        .wait();

    assert_eq!(first.accepted, Some(full), "{first:?}");
    assert_eq!(second.accepted, Some(full), "cache-seeded verdict is bit-identical");
    for (a, b) in first.segments.iter().zip(&second.segments) {
        assert_eq!(a.accepted, b.accepted, "segment roots identical across runs");
        assert_eq!(a.seeded_from, b.seeded_from);
        assert_eq!(a.steps_trained, b.steps_trained, "cache seeding keeps delta training");
    }

    let report = delegation.finish();
    // Job 1 streams both transfers (two cache misses, two inserts); job 2
    // hits both certified roots and never opens a stream.
    assert_eq!(report.ckpt_cache_misses, 2, "{report:?}");
    assert_eq!(report.ckpt_cache_hits, 2, "{report:?}");
    let snap = registry.snapshot();
    assert_eq!(snap.counter("coord_ckpt_cache_hits"), report.ckpt_cache_hits);
    assert_eq!(snap.counter("coord_ckpt_cache_misses"), report.ckpt_cache_misses);
    assert!(snap.gauge("coord_ckpt_cache_bytes") > 0, "certified states are resident");
    assert!(snap.gauge("coord_stream_peak_bytes") > 0, "job 1 streamed its seeds");
    assert_eq!(snap.counter("coord_overloads"), report.overloads);
    let json = report.to_json();
    assert!(json.contains("\"ckpt_cache_hits\":2"), "{json}");
    assert!(json.contains("\"ckpt_cache_misses\":2"), "{json}");
    assert!(json.contains("\"overloads\":"), "{json}");
    assert_eq!(pool.idle(), 2, "all leases (including stream sources) returned");
}

/// A winning group whose certified manifest advertises more than
/// `max_checkpoint_bytes` is treated as refusing state transfer: the
/// successor re-trains its prefix (`seeded_from == None`, full prefix
/// steps), the refusal is visible in the report, and the verdict is
/// unharmed — no truncation, no wedge.
#[test]
fn oversize_manifest_is_refused_and_successor_retrains_prefix() {
    let pool = in_process_pool(&[("w0", FaultPlan::Honest), ("w1", FaultPlan::Honest)]);
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let full = honest(spec);

    let mut cfg = ServiceConfig::new(2);
    cfg.max_checkpoint_bytes = 8; // no real checkpoint encodes this small
    let delegation = Delegation::start(&pool, cfg);
    let outcome = delegation
        .submit(JobRequest::new(spec).with_segments(2).with_state_transfer())
        .wait();

    assert_eq!(outcome.accepted, Some(full), "{outcome:?}");
    assert_eq!(outcome.segments.len(), 2);
    let s1 = &outcome.segments[1];
    assert_eq!(s1.seeded_from, None, "the refused manifest left the successor unseeded");
    assert_eq!(s1.steps_trained, 8, "fallback pays the full prefix");
    assert_eq!(s1.requeues, 0, "refusal is not a failure — no re-queue burned");

    let report = delegation.finish();
    assert_eq!(report.total_seeded_segments(), 0);
    assert_eq!(report.ckpt_cache_hits, 0);
    assert_eq!(pool.idle(), 2);
}

/// Both readiness backends (the scan loop everywhere, epoll where the
/// kernel has it) drive the same streamed, mux-linked delegation to
/// bit-identical verdicts and per-boundary state roots — the
/// backend-equivalence acceptance for the event core.
#[test]
fn stream_verdicts_bit_identical_across_readiness_backends() {
    let spec = JobSpec::quick(Preset::Mlp, 8);
    let full = honest(spec);
    let backends = if Readiness::available() {
        vec![BackendKind::Scan, BackendKind::Epoll]
    } else {
        vec![BackendKind::Scan]
    };

    let mut runs: Vec<(BackendKind, Option<Hash>, Vec<Option<Hash>>)> = Vec::new();
    for kind in backends {
        let mux = Mux::with_backend(kind);
        let mut servers = Vec::new();
        let mut workers = Vec::new();
        for name in ["w0", "w1", "w2"] {
            let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
            let addr = listener.local_addr().unwrap();
            servers.push(spawn_server(
                listener,
                WorkerHost::new(name, FaultPlan::Honest),
                Some(1),
            ));
            let conn = mux.connect(name, addr).expect("connect worker");
            workers.push(PooledWorker::mux(name, conn));
        }
        let pool = WorkerPool::new(workers);
        let delegation = Delegation::start(&pool, ServiceConfig::new(2));
        let outcome = delegation
            .submit(JobRequest::new(spec).with_segments(4).with_state_transfer())
            .wait();
        let roots = outcome.segments.iter().map(|s| s.accepted).collect();
        let report = delegation.finish();
        assert!(report.total_seeded_segments() >= 1, "transfer ran under {kind:?}");
        for mut w in pool.into_workers() {
            let _ = w.call(Request::Shutdown);
        }
        for server in servers {
            let _ = server.join();
        }
        drop(mux);
        runs.push((kind, outcome.accepted, roots));
    }

    let (_, accepted0, roots0) = &runs[0];
    assert_eq!(*accepted0, Some(full));
    for (kind, accepted, roots) in &runs[1..] {
        assert_eq!(accepted, accepted0, "verdict differs under {kind:?}");
        assert_eq!(roots, roots0, "boundary roots differ under {kind:?}");
    }
}
