//! REFCOST — paper §2.2's claim: resolving a single operator needs ~two
//! orders of magnitude less referee compute/communication than re-running
//! the full training step (let alone the whole program).
//!
//! Measured: referee wall time + bytes for a real dispute vs (a) the cost
//! of re-executing one full training step and (b) transferring a full
//! checkpoint.
//!
//! Run: `cargo bench --bench referee_costs`

use std::time::{Duration, Instant};

use verde::graph::executor::{execute, ExecOpts};
use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::util::bench::time_adaptive;
use verde::util::metrics::human_bytes;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn main() {
    println!("REFCOST: referee cost vs naive re-execution");
    for preset in [Preset::LlamaTiny, Preset::LlamaSmall] {
        let mut spec = JobSpec::quick(preset, 16);
        spec.batch = 2;
        spec.seq = 32;
        let session = Session::new(spec);
        let state_bytes = session.genesis.byte_len() as u64;
        let batch = session.batch(1);

        // cost of the naive referee: re-run one full step + receive state
        let full_step = time_adaptive("full step", Duration::from_millis(800), 20, || {
            execute(&session.program.graph, &session.genesis, &batch, Backend::Rep, 1, &ExecOpts::default())
        });

        // actual dispute — tamper a mid-graph matmul (the paper's §2.2
        // example operator); Case 3 then recomputes exactly that matmul.
        // Worst case instead is an embedding-sized update node, reported
        // separately below.
        // NOTE: not the q-projection — element (0,0) of q is absorbed by
        // position 0's single-entry causal softmax (zero gradient), so a
        // tamper there provably never reaches the output. The MLP gate
        // matmul feeds the residual stream directly.
        let mm = session
            .program
            .graph
            .nodes
            .iter()
            .position(|n| matches!(n.op, verde::graph::Op::MatMul) && n.label.contains("mlp.gate"))
            .unwrap();
        let upd = *session.program.param_updates.values().map(|s| &s.node).min().unwrap();
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new(
            "cheat",
            spec,
            Backend::Rep,
            Fault::TamperOutput { step: 9, node: mm, delta: 1.0 },
        );
        honest.train();
        cheat.train();
        let t0 = Instant::now();
        let r = run_dispute(spec, honest, cheat);
        let dispute_wall = t0.elapsed();
        assert_eq!(r.verdict.convicted(), Some(1));
        let moved = r.bytes[0] + r.bytes[1];
        println!("  {}:", preset.name());
        println!(
            "    full step re-execution  {:>12?}   checkpoint transfer {:>12}",
            full_step.median,
            human_bytes(state_bytes)
        );
        println!(
            "    dispute total (wall)    {:>12?}   protocol bytes      {:>12}",
            dispute_wall,
            human_bytes(moved)
        );
        println!(
            "    communication ratio: {:.1}x less than a checkpoint transfer",
            state_bytes as f64 / moved as f64
        );
        println!("    referee counters: {}", r.referee.to_json());
        println!(
            "JSON {{\"bench\":\"refcost\",\"model\":\"{}\",\"full_step_s\":{:.6},\"dispute_wall_s\":{:.6},\"state_bytes\":{state_bytes},\"protocol_bytes\":{moved}}}",
            preset.name(),
            full_step.median_secs(),
            dispute_wall.as_secs_f64()
        );

        // worst-case disputed operator: the embedding-table Adam update
        let mut honest2 = TrainerNode::honest("honest", spec);
        let mut cheat2 = TrainerNode::new(
            "cheat",
            spec,
            Backend::Rep,
            Fault::TamperOutput { step: 9, node: upd, delta: 0.01 },
        );
        honest2.train();
        cheat2.train();
        let r2 = run_dispute(spec, honest2, cheat2);
        assert_eq!(r2.verdict.convicted(), Some(1));
        let moved2 = r2.bytes[0] + r2.bytes[1];
        println!(
            "    worst-case op (embed update): protocol bytes {:>12}  ({:.1}x less than checkpoint)",
            human_bytes(moved2),
            state_bytes as f64 / moved2 as f64
        );
    }
    println!("\npaper reference: single-operator resolution cuts referee compute+comm");
    println!("by ~2 orders of magnitude vs re-running/receiving a full step (§2.2).");
}
