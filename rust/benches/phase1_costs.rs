//! P1COST — paper §2.1 cost analysis of multi-level checkpointing:
//! re-execution fraction and storage vs the per-level checkpoint count N.
//!
//! Paper: N=20 → re-execute <6% of training; N=100 → <1.1%; storage for
//! Llama-8B weights: ~hundreds of GB (N=20) to ~TBs (N=100).
//!
//! Ours: analytic bound + MEASURED re-execution from real disputes at each
//! N, plus measured checkpoint storage for our models and the projected
//! paper-model numbers.
//!
//! Run: `cargo bench --bench phase1_costs`

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::train::checkpoint::{
    adam_state_bytes, reexec_fraction_bound, storage_bytes, PAPER_MODELS,
};
use verde::train::JobSpec;
use verde::util::metrics::human_bytes;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn measured_reexec(n: u64, steps: u64) -> (f64, u64) {
    let mut spec = JobSpec::quick(Preset::Mlp, steps);
    spec.checkpoint_n = n;
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        // worst-ish case: late divergence
        Fault::WrongData { step: steps - 1 },
    );
    honest.train();
    cheat.train();
    let stored = honest.counters.get("checkpoint_bytes_stored");
    let r = run_dispute(spec, honest, cheat);
    assert_eq!(r.verdict.convicted(), Some(1));
    // count re-executed steps on a fresh honest trainer equal to trainer0's
    // counters — reported by the dispute participants
    (r.phase1_rounds as f64, stored)
}

fn main() {
    println!("P1COST: multi-level checkpoint schedule costs");
    println!(
        "{:>6} {:>12} {:>14} {:>10} {:>14}",
        "N", "bound", "measured", "rounds", "storage"
    );
    let steps = 512u64;
    for n in [5u64, 10, 20, 100] {
        let bound = reexec_fraction_bound(n);
        // measured: run a dispute and read the honest trainer's counter
        let mut spec = JobSpec::quick(Preset::Mlp, steps);
        spec.checkpoint_n = n;
        let mut honest = TrainerNode::honest("honest", spec);
        let mut cheat = TrainerNode::new(
            "cheat",
            spec,
            Backend::Rep,
            Fault::WrongData { step: steps - 1 },
        );
        honest.train();
        cheat.train();
        let stored = honest.counters.get("checkpoint_bytes_stored");
        // run the dispute with endpoint wrappers that keep ownership
        let r = run_dispute(spec, &mut honest, &mut cheat);
        assert_eq!(r.verdict.convicted(), Some(1));
        let reexec = honest.counters.get("steps_reexecuted") as f64 / steps as f64;
        println!(
            "{:>6} {:>11.2}% {:>13.2}% {:>10} {:>14}",
            n,
            bound * 100.0,
            reexec * 100.0,
            r.phase1_rounds,
            human_bytes(stored)
        );
        println!(
            "JSON {{\"bench\":\"p1cost\",\"n\":{n},\"bound\":{bound:.4},\"measured\":{reexec:.4},\"rounds\":{},\"storage_bytes\":{stored}}}",
            r.phase1_rounds
        );
    }

    println!("\n  projected level-0 storage for the paper's models:");
    for (name, params) in PAPER_MODELS {
        let w = params * 4; // weights only, as the paper counts for storage
        let full = adam_state_bytes(params);
        println!(
            "  {:<16} N=20: {:>12} (weights) / {:>12} (with Adam)   N=100: {:>12} / {:>12}",
            name,
            human_bytes(storage_bytes(20, w)),
            human_bytes(storage_bytes(20, full)),
            human_bytes(storage_bytes(100, w)),
            human_bytes(storage_bytes(100, full)),
        );
    }
    println!("\npaper reference: N=20 <6% re-execution, few-hundred-GB storage (Llama-8B);");
    println!("                 N=100 <1.1%, few TB.");
}
