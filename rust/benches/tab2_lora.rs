//! TAB2 — paper Table 2: Llama-8B inference and LoRA fine-tuning overheads
//! on the A100-80G profile (paper: 98% inference, 126% LoRA fine-tune).
//!
//! Ours: llama-base (the 8B stand-in) + rank-8 LoRA adapters; the
//! fine-tuning step trains adapters only (frozen base), as in the paper.
//!
//! Run: `cargo bench --bench tab2_lora`

use std::time::Duration;

use verde::graph::autodiff::Optimizer;
use verde::graph::executor::{execute, ExecOpts};
use verde::graph::kernels::Backend;
use verde::model::lora::llama_base_lora;
use verde::model::Preset;
use verde::tensor::profile::HardwareProfile;
use verde::train::data::DataGen;
use verde::util::bench::{overhead_pct, time_adaptive};

fn main() {
    println!("TAB2: Llama-8B stand-in (llama-base) + LoRA(r=8), profile A100-80G");
    let (batch, seq) = (2usize, 32usize);
    let model = llama_base_lora(8, batch, seq);
    let opt = Optimizer::adam(1e-3);
    let train = model.train_step(&opt);
    let state = model.init_state(3, &opt);
    let data = DataGen::new(Preset::LlamaBase, batch, seq, 5);
    let b = data.batch(1);
    let hw = HardwareProfile::A100_80G;
    let budget = Duration::from_millis(1200);

    let inf_rep = time_adaptive("inf rep", budget, 30, || {
        execute(&model.builder.graph, &state, &b, Backend::Rep, 1, &ExecOpts::default())
    });
    let inf_free = time_adaptive("inf free", budget, 30, || {
        execute(&model.builder.graph, &state, &b, Backend::Free(hw), 1, &ExecOpts::default())
    });
    let ft_rep = time_adaptive("ft rep", budget, 30, || {
        execute(&train.graph, &state, &b, Backend::Rep, 1, &ExecOpts::default())
    });
    let ft_free = time_adaptive("ft free", budget, 30, || {
        execute(&train.graph, &state, &b, Backend::Free(hw), 1, &ExecOpts::default())
    });
    let oi = overhead_pct(&inf_rep, &inf_free);
    let of = overhead_pct(&ft_rep, &ft_free);
    println!(
        "  inference overhead: {oi:.1}%   (rep {:.1} ms vs free {:.1} ms)",
        inf_rep.median_secs() * 1e3,
        inf_free.median_secs() * 1e3
    );
    println!(
        "  LoRA ft overhead:   {of:.1}%   (rep {:.1} ms vs free {:.1} ms)",
        ft_rep.median_secs() * 1e3,
        ft_free.median_secs() * 1e3
    );
    println!(
        "JSON {{\"bench\":\"tab2\",\"infer_overhead_pct\":{oi:.2},\"lora_overhead_pct\":{of:.2}}}"
    );
    println!("\npaper reference (A100-80G): inference 98%, LoRA fine-tuning 126%");
}
