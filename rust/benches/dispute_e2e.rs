//! DISP — end-to-end dispute cost scaling: wall time, bytes, and rounds vs
//! training length n and checkpoint count N (the paper's "practical
//! overheads for compute providers" claim, §1/§2.1).
//!
//! Run: `cargo bench --bench dispute_e2e`

use std::time::Instant;

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::train::JobSpec;
use verde::util::metrics::human_bytes;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn main() {
    println!("DISP: dispute cost vs training length and checkpoint count");
    println!(
        "{:>7} {:>5} {:>12} {:>10} {:>12} {:>12} {:>8}",
        "steps", "N", "train wall", "disp wall", "bytes", "reexec", "rounds"
    );
    for steps in [64u64, 256] {
        for n in [4u64, 20] {
            let mut spec = JobSpec::quick(Preset::LlamaTiny, steps);
            spec.checkpoint_n = n;
            let mut honest = TrainerNode::honest("honest", spec);
            let mut cheat = TrainerNode::new(
                "cheat",
                spec,
                Backend::Rep,
                Fault::WrongData { step: steps * 3 / 4 },
            );
            let t0 = Instant::now();
            honest.train();
            let train_wall = t0.elapsed();
            cheat.train();
            let t1 = Instant::now();
            let r = run_dispute(spec, &mut honest, &mut cheat);
            let disp_wall = t1.elapsed();
            assert_eq!(r.verdict.convicted(), Some(1));
            let moved = r.bytes[0] + r.bytes[1];
            let reexec = honest.counters.get("steps_reexecuted")
                + cheat.counters.get("steps_reexecuted");
            println!(
                "{:>7} {:>5} {:>12?} {:>10?} {:>12} {:>12} {:>8}",
                steps,
                n,
                train_wall,
                disp_wall,
                human_bytes(moved),
                format!("{reexec} steps"),
                r.phase1_rounds
            );
            println!(
                "JSON {{\"bench\":\"disp\",\"steps\":{steps},\"n\":{n},\"train_s\":{:.4},\"dispute_s\":{:.4},\"bytes\":{moved},\"reexec_steps\":{reexec},\"rounds\":{}}}",
                train_wall.as_secs_f64(),
                disp_wall.as_secs_f64(),
                r.phase1_rounds
            );
        }
    }
    println!("\ndispute cost should stay a small fraction of training cost and");
    println!("scale ~logarithmically (levels) in n — paper §2.1.");
}
