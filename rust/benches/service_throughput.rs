//! SERVICE — delegation-service load generator: N concurrent jobs × k
//! workers with honest and faulty mixes, tracking service-level jobs/sec,
//! mean latency, and protocol bytes/job. Emits `BENCH_service.json` so the
//! perf trajectory of the coordinator is machine-readable run over run.
//!
//! Run: `cargo bench --bench service_throughput`

use std::time::Instant;

use verde::model::Preset;
use verde::net::threaded::spawn;
use verde::service::{run_service, FaultPlan, PooledWorker, WorkerHost, WorkerPool};
use verde::train::JobSpec;
use verde::util::metrics::human_bytes;

struct Scenario {
    name: &'static str,
    workers: usize,
    faulty: usize,
    k: usize,
    jobs: u64,
    steps: u64,
}

/// Worker `i` of `n` gets a fault from a small rotating menu when it is one
/// of the `faulty` first slots.
fn plan_for(i: usize, faulty: usize) -> FaultPlan {
    if i >= faulty {
        return FaultPlan::Honest;
    }
    match i % 3 {
        0 => FaultPlan::Tamper { step: Some(2), delta: 0.05 },
        1 => FaultPlan::WrongData { step: Some(3) },
        _ => FaultPlan::SkipSteps { after: Some(2) },
    }
}

fn run_scenario(sc: &Scenario) -> String {
    // Workers as independent thread actors (the same WorkerHost code path
    // a TCP worker process runs), so jobs genuinely execute in parallel.
    let pool = WorkerPool::new(
        (0..sc.workers)
            .map(|i| {
                let name = format!("w{i}");
                PooledWorker::new(&name, spawn(WorkerHost::new(&name, plan_for(i, sc.faulty))))
            })
            .collect(),
    );
    let jobs: Vec<JobSpec> = (0..sc.jobs)
        .map(|i| {
            let mut spec = JobSpec::quick(Preset::Mlp, sc.steps);
            spec.data_seed = spec.data_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
            spec
        })
        .collect();

    let t0 = Instant::now();
    let report = run_service(jobs, &pool, sc.k);
    let wall = t0.elapsed();

    let resolved = report.outcomes.iter().filter(|o| o.accepted.is_some()).count();
    println!(
        "  {:<18} {:>3} jobs  k={} over {:>2} workers ({} faulty)  {:>10.2?}  {:>7.2} jobs/s  {:>10}/job  {:>3} disputes",
        sc.name,
        report.outcomes.len(),
        sc.k,
        sc.workers,
        sc.faulty,
        wall,
        report.jobs_per_sec(),
        human_bytes(report.bytes_per_job() as u64),
        report.total_disputes(),
    );
    assert_eq!(resolved, report.outcomes.len(), "all jobs must resolve");

    format!(
        "{{\"name\":\"{}\",\"jobs\":{},\"k\":{},\"workers\":{},\"faulty\":{},\"steps\":{},\
         \"wall_s\":{:.6},\"jobs_per_sec\":{:.3},\"mean_latency_s\":{:.6},\
         \"total_bytes\":{},\"bytes_per_job\":{:.1},\"disputes\":{}}}",
        sc.name,
        report.outcomes.len(),
        sc.k,
        sc.workers,
        sc.faulty,
        sc.steps,
        wall.as_secs_f64(),
        report.jobs_per_sec(),
        report.mean_latency().as_secs_f64(),
        report.total_bytes(),
        report.bytes_per_job(),
        report.total_disputes(),
    )
}

fn main() {
    println!("SERVICE: delegation-service throughput (jobs/sec, bytes/job)");
    let scenarios = [
        Scenario { name: "honest_w4_k2", workers: 4, faulty: 0, k: 2, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w4_k2", workers: 4, faulty: 1, k: 2, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w4_k4", workers: 4, faulty: 2, k: 4, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w8_k2", workers: 8, faulty: 2, k: 2, jobs: 16, steps: 6 },
        Scenario { name: "adversarial_w6_k3", workers: 6, faulty: 3, k: 3, jobs: 9, steps: 6 },
    ];
    let lines: Vec<String> = scenarios.iter().map(run_scenario).collect();
    let json = format!("[\n  {}\n]\n", lines.join(",\n  "));
    for line in &lines {
        println!("JSON {line}");
    }
    match std::fs::write("BENCH_service.json", &json) {
        Ok(()) => println!("wrote BENCH_service.json"),
        Err(e) => eprintln!("could not write BENCH_service.json: {e}"),
    }
}
