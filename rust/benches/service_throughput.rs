//! SERVICE — delegation-service load generator, two parts:
//!
//! 1. In-process scenarios (honest and adversarial worker mixes) through
//!    the event-driven coordinator: jobs/sec, mean latency, bytes/job.
//! 2. **Blocking vs multiplexed dispatch** over real TCP worker fleets at
//!    pool sizes {4, 16, 64}: the thread-per-dispatch baseline
//!    (`run_service_blocking`) against the event core (`run_service`) with
//!    its fixed coordinator thread budget. The acceptance bar: the
//!    multiplexed coordinator drives 64 workers with ≤ 8 coordinator
//!    threads at jobs/sec no worse than the blocking path at pool size 4.
//!
//! A third part runs one delegation with span tracing enabled and reports
//! the per-job submit→settle latency distribution (p50/p90/p99) straight
//! from the coordinator's span timelines. A fourth compares the optimistic
//! staked audit tier against k-replication on the same sharded job: same
//! verdict, strictly fewer worker-steps (`(1 + audit_rate)·steps` expected
//! vs `k·steps`).
//!
//! Two more rows land in the JSON: a **fleet-size sweep** over {64, 256,
//! 1024} open mux connections on one event loop (mean per-tick poll cost
//! from `net_mux_poll_us`, plus the coordinator's peak buffered stream
//! bytes — asserted to stay inside the chunk window), and a
//! **journal-on vs journal-off** pass measuring the write-ahead journal's
//! fsync overhead on the same job batch.
//!
//! Emits `BENCH_service.json` (throughput + latency percentiles) and
//! `STATS_snapshot.json` (the live stats snapshot of the traced run) so
//! the perf trajectory of the coordinator is machine-readable run over
//! run.
//!
//! Run: `cargo bench --bench service_throughput`

use std::net::{SocketAddr, TcpListener};
use std::thread::JoinHandle;
use std::time::Instant;

use verde::model::Preset;
use verde::net::mux::Mux;
use verde::net::tcp::{spawn_server, TcpEndpoint};
use verde::net::Endpoint as _;
use verde::net::threaded::spawn;
use verde::obs::LATENCY_US_BOUNDS;
use verde::service::{
    run_service, run_service_blocking, Delegation, FaultPlan, JobRequest, PooledWorker,
    ServiceConfig, ServiceReport, WorkerHost, WorkerPool,
};
use verde::train::JobSpec;
use verde::util::metrics::human_bytes;
use verde::verde::protocol::Request;
use verde::verde::wire::CHECKPOINT_CHUNK;

struct Scenario {
    name: &'static str,
    workers: usize,
    faulty: usize,
    k: usize,
    jobs: u64,
    steps: u64,
}

/// Worker `i` of `n` gets a fault from a small rotating menu when it is one
/// of the `faulty` first slots.
fn plan_for(i: usize, faulty: usize) -> FaultPlan {
    if i >= faulty {
        return FaultPlan::Honest;
    }
    match i % 3 {
        0 => FaultPlan::Tamper { step: Some(2), delta: 0.05 },
        1 => FaultPlan::WrongData { step: Some(3) },
        _ => FaultPlan::SkipSteps { after: Some(2) },
    }
}

fn job_batch(n: u64, steps: u64) -> Vec<JobSpec> {
    (0..n)
        .map(|i| {
            let mut spec = JobSpec::quick(Preset::Mlp, steps);
            spec.data_seed = spec.data_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
            spec
        })
        .collect()
}

fn report_json(
    name: &str,
    mode: &str,
    sc_threads: usize,
    report: &ServiceReport,
    faulty: usize,
    steps: u64,
) -> String {
    format!(
        "{{\"name\":\"{}\",\"mode\":\"{}\",\"jobs\":{},\"k\":{},\"workers\":{},\"faulty\":{},\
         \"steps\":{},\"coordinator_threads\":{},\"wall_s\":{:.6},\"jobs_per_sec\":{:.3},\
         \"mean_latency_s\":{:.6},\"total_bytes\":{},\"bytes_per_job\":{:.1},\"disputes\":{},\
         \"eliminated\":{},\"requeued\":{}}}",
        name,
        mode,
        report.outcomes.len(),
        report.k,
        report.workers,
        faulty,
        steps,
        sc_threads,
        report.wall.as_secs_f64(),
        report.jobs_per_sec(),
        report.mean_latency().as_secs_f64(),
        report.total_bytes(),
        report.bytes_per_job(),
        report.total_disputes(),
        report.total_eliminated(),
        report.total_requeued(),
    )
}

fn run_scenario(sc: &Scenario) -> String {
    // Workers as independent thread actors (the same WorkerHost code path
    // a TCP worker process runs), so jobs genuinely execute in parallel.
    let pool = WorkerPool::new(
        (0..sc.workers)
            .map(|i| {
                let name = format!("w{i}");
                PooledWorker::new(&name, spawn(WorkerHost::new(&name, plan_for(i, sc.faulty))))
            })
            .collect(),
    );
    let jobs = job_batch(sc.jobs, sc.steps);

    let t0 = Instant::now();
    let report = run_service(jobs, &pool, sc.k);
    let wall = t0.elapsed();

    let resolved = report.outcomes.iter().filter(|o| o.accepted.is_some()).count();
    println!(
        "  {:<18} {:>3} jobs  k={} over {:>2} workers ({} faulty)  {:>10.2?}  {:>7.2} jobs/s  {:>10}/job  {:>3} disputes",
        sc.name,
        report.outcomes.len(),
        sc.k,
        sc.workers,
        sc.faulty,
        wall,
        report.jobs_per_sec(),
        human_bytes(report.bytes_per_job() as u64),
        report.total_disputes(),
    );
    assert_eq!(resolved, report.outcomes.len(), "all jobs must resolve");
    report_json(sc.name, "event", report.threads, &report, sc.faulty, sc.steps)
}

/// Spawn `n` honest TCP worker "processes" (one server thread each — those
/// are worker-side, not coordinator-side, threads) on ephemeral ports.
fn tcp_fleet(n: usize) -> (Vec<JoinHandle<WorkerHost>>, Vec<SocketAddr>) {
    (0..n)
        .map(|i| {
            let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
            let addr = listener.local_addr().unwrap();
            let name = format!("w{i}");
            (
                spawn_server(listener, WorkerHost::new(&name, FaultPlan::Honest), Some(1)),
                addr,
            )
        })
        .unzip()
}

/// One blocking-vs-mux comparison point: `size` TCP workers, k=4.
/// Returns (json, jobs_per_sec, coordinator_threads).
fn run_tcp_dispatch(size: usize, mux_mode: bool) -> (String, f64, usize) {
    let k = 4.min(size);
    let n_jobs = size.clamp(8, 32) as u64;
    let steps = 3;
    let (servers, addrs) = tcp_fleet(size);

    let mux = if mux_mode { Some(Mux::new()) } else { None };
    let pool = WorkerPool::new(
        addrs
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                let name = format!("w{i}");
                match &mux {
                    Some(mux) => {
                        PooledWorker::mux(&name, mux.connect(&name, addr).expect("connect"))
                    }
                    None => {
                        let ep = TcpEndpoint::connect(&name, addr).expect("connect");
                        PooledWorker::new(&name, ep)
                    }
                }
            })
            .collect(),
    );
    let jobs = job_batch(n_jobs, steps);

    let report = if mux_mode {
        run_service(jobs, &pool, k)
    } else {
        run_service_blocking(jobs, &pool, k)
    };
    let resolved = report.outcomes.iter().filter(|o| o.accepted.is_some()).count();
    assert_eq!(resolved, report.outcomes.len(), "all jobs must resolve");

    // Coordinator-side thread budget: the event core is 1 event loop +
    // resolvers + 1 shared mux driver; the blocking baseline is lanes ×
    // (1 + k) at peak. Worker server threads are the fleet, not the
    // coordinator.
    let threads = report.threads + usize::from(mux_mode);
    let mode = if mux_mode { "mux" } else { "blocking" };
    let name = format!("{mode}_w{size}_k{k}");
    println!(
        "  {:<18} {:>3} jobs  k={k} over {:>2} TCP workers  {:>10.2?}  {:>7.2} jobs/s  {:>2} coordinator threads",
        name,
        report.outcomes.len(),
        size,
        report.wall,
        report.jobs_per_sec(),
        threads,
    );

    let jps = report.jobs_per_sec();
    let json = report_json(&name, mode, threads, &report, 0, steps);

    // Orderly teardown: shut the fleet down and join the server threads.
    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    drop(mux);
    for s in servers {
        let _ = s.join();
    }
    (json, jps, threads)
}

/// Fleet-size sweep point: `size` open mux connections on ONE event loop
/// and one mux driver, with a small sharded-transfer job batch active at
/// a time (the realistic shape: a large registered fleet, a few leases
/// hot). Records the mean per-tick poll cost from the `net_mux_poll_us`
/// histogram delta — with the epoll backend this tracks *ready*
/// connections, not open ones — and the coordinator's peak buffered
/// stream bytes, which must stay inside the chunk window no matter the
/// fleet or checkpoint size.
fn run_fleet_sweep(size: usize) -> String {
    let k = 4;
    let n_jobs = 4u64;
    let steps = 8u64;
    let segments = 4u64;
    let cfg = ServiceConfig::new(k);
    let (servers, addrs) = tcp_fleet(size);
    let mux = Mux::new();
    let pool = WorkerPool::new(
        addrs
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                let name = format!("w{i}");
                PooledWorker::mux(&name, mux.connect(&name, addr).expect("connect"))
            })
            .collect(),
    );

    let poll = verde::obs::global().histogram("net_mux_poll_us", &LATENCY_US_BOUNDS);
    let (ticks0, us0) = (poll.count(), poll.sum());

    let delegation = Delegation::start(&pool, cfg);
    let registry = delegation.registry().clone();
    let t0 = Instant::now();
    let handles: Vec<_> = job_batch(n_jobs, steps)
        .into_iter()
        .map(|spec| {
            delegation.submit(JobRequest::new(spec).with_segments(segments).with_state_transfer())
        })
        .collect();
    let resolved = handles.iter().filter(|h| h.wait().accepted.is_some()).count();
    let wall = t0.elapsed();
    assert_eq!(resolved, n_jobs as usize, "all jobs must resolve");
    let report = delegation.finish();

    let ticks = poll.count() - ticks0;
    let mean_poll_us = (poll.sum() - us0) as f64 / ticks.max(1) as f64;
    let snap = registry.snapshot();
    let peak = snap.gauge("coord_stream_peak_bytes");
    let window_bytes = (cfg.stream_window as u64 + 1) * CHECKPOINT_CHUNK as u64;
    assert!(
        peak <= window_bytes,
        "peak buffered stream bytes ({peak}) must stay inside the chunk window ({window_bytes})"
    );
    let backend = verde::obs::global().gauge("net_readiness_backend").get();
    println!(
        "  fleet_w{:<5}       {:>3} jobs  k={k} over {:>4} mux conns  {:>10.2?}  {:>8.1} us/poll-tick  peak stream {:>10}",
        size,
        n_jobs,
        size,
        wall,
        mean_poll_us,
        human_bytes(peak),
    );

    let json = format!(
        "{{\"name\":\"fleet_w{}\",\"mode\":\"mux\",\"conns\":{},\"jobs\":{},\"k\":{},\
         \"wall_s\":{:.6},\"poll_ticks\":{},\"mean_poll_us\":{:.2},\"peak_stream_bytes\":{},\
         \"readiness_backend\":{},\"transfer_bytes\":{},\"seeded_segments\":{}}}",
        size,
        size,
        n_jobs,
        k,
        wall.as_secs_f64(),
        ticks,
        mean_poll_us,
        peak,
        backend,
        report.total_transfer_bytes(),
        report.total_seeded_segments(),
    );

    for mut w in pool.into_workers() {
        let _ = w.call(Request::Shutdown);
    }
    drop(mux);
    for s in servers {
        let _ = s.join();
    }
    json
}

/// Journal-on vs journal-off: the same job batch against identical fresh
/// in-process pools, once ephemeral and once with the write-ahead journal
/// (every state transition appended, settlement boundaries fsynced). The
/// wall delta plus the journal's own entry/sync counters make the
/// durability tax a tracked number instead of folklore.
fn run_journal_compare(smoke: bool) -> Vec<String> {
    let (jobs, steps) = if smoke { (8u64, 4u64) } else { (32, 6) };
    let k = 2;
    let path = "BENCH_journal.wal";
    let mut out = Vec::new();
    for &durable in &[false, true] {
        let pool = WorkerPool::new(
            (0..4)
                .map(|i| {
                    let name = format!("w{i}");
                    PooledWorker::new(&name, spawn(WorkerHost::new(&name, FaultPlan::Honest)))
                })
                .collect(),
        );
        let delegation = if durable {
            Delegation::start_durable(&pool, ServiceConfig::new(k), path)
                .expect("create bench journal")
        } else {
            Delegation::start(&pool, ServiceConfig::new(k))
        };
        let registry = delegation.registry().clone();
        let t0 = Instant::now();
        let handles: Vec<_> = job_batch(jobs, steps)
            .into_iter()
            .map(|spec| delegation.submit(JobRequest::new(spec)))
            .collect();
        let resolved = handles.iter().filter(|h| h.wait().accepted.is_some()).count();
        let wall = t0.elapsed();
        assert_eq!(resolved, jobs as usize, "all jobs must resolve");
        let report = delegation.finish();
        let snap = registry.snapshot();
        let (entries, syncs, jbytes) = (
            snap.counter("coord_journal_entries"),
            snap.counter("coord_journal_syncs"),
            snap.counter("coord_journal_bytes"),
        );
        let mode = if durable { "durable" } else { "ephemeral" };
        println!(
            "  journal_{:<9}  {:>3} jobs  k={k}  {:>10.2?}  {:>7.2} jobs/s  {:>4} entries  {:>4} fsyncs  {:>10} journaled",
            mode,
            jobs,
            wall,
            report.jobs_per_sec(),
            entries,
            syncs,
            human_bytes(jbytes),
        );
        out.push(format!(
            "{{\"name\":\"journal_{}\",\"mode\":\"{}\",\"jobs\":{},\"k\":{},\"wall_s\":{:.6},\
             \"jobs_per_sec\":{:.3},\"journal_entries\":{},\"journal_syncs\":{},\
             \"journal_bytes\":{}}}",
            mode,
            mode,
            jobs,
            k,
            wall.as_secs_f64(),
            report.jobs_per_sec(),
            entries,
            syncs,
            jbytes,
        ));
        if durable {
            let _ = std::fs::remove_file(path);
        }
    }
    out
}

/// Sharded-with-transfer vs prefix-retrain: the same sharded job run both
/// ways against identical fresh pools. The acceptance bar: transfer
/// executes exactly `k × steps` worker-steps (each segment trains only its
/// delta) while prefix re-training pays `k × Σ b_i`, and both reach the
/// same verdict.
fn run_transfer_compare(steps: u64, segments: u64) -> Vec<String> {
    let k = 2;
    let spec = {
        let mut s = JobSpec::quick(Preset::Mlp, steps);
        s.data_seed ^= 0x7273; // distinct stream from the scenario jobs
        s
    };
    let mut out = Vec::new();
    let mut verdicts = Vec::new();
    for &transfer in &[false, true] {
        let pool = WorkerPool::new(
            (0..4)
                .map(|i| {
                    let name = format!("w{i}");
                    PooledWorker::new(&name, spawn(WorkerHost::new(&name, FaultPlan::Honest)))
                })
                .collect(),
        );
        let delegation = Delegation::start(&pool, ServiceConfig::new(k));
        let mut req = JobRequest::new(spec).with_segments(segments);
        if transfer {
            req = req.with_state_transfer();
        }
        let t0 = Instant::now();
        let outcome = delegation.submit(req).wait();
        let wall = t0.elapsed();
        assert!(outcome.accepted.is_some(), "sharded job must resolve");
        verdicts.push(outcome.accepted);
        let report = delegation.finish();
        let mode = if transfer { "transfer" } else { "prefix" };
        println!(
            "  shard_{:<10} 1 job   k={k} x{segments} segments of {steps} steps  {:>10.2?}  {:>5} worker-steps  {:>10} transferred",
            mode,
            wall,
            report.total_steps_trained(),
            human_bytes(report.total_transfer_bytes()),
        );
        if transfer {
            assert_eq!(
                report.total_steps_trained(),
                k as u64 * steps,
                "transfer must train exactly k x steps worker-steps"
            );
        }
        out.push(format!(
            "{{\"name\":\"shard_{}_s{}x{}\",\"mode\":\"{}\",\"k\":{},\"wall_s\":{:.6},\
             \"worker_steps\":{},\"transfer_bytes\":{},\"seeded_segments\":{}}}",
            mode,
            steps,
            segments,
            mode,
            k,
            wall.as_secs_f64(),
            report.total_steps_trained(),
            report.total_transfer_bytes(),
            report.total_seeded_segments(),
        ));
    }
    assert_eq!(verdicts[0], verdicts[1], "transfer and prefix verdicts must agree");
    out
}

/// Optimistic audit tier vs k-replication: the same sharded job run both
/// ways against identical fresh honest pools. The acceptance bar: the
/// optimistic run settles the same verdict for `steps + Σ sampled-segment
/// lengths` worker-steps — strictly less than the replicated `k × steps`
/// (the sampler is deterministic in (audit_seed=0, job 0), which samples
/// a strict subset of segments at rate 0.5).
fn run_audit_compare(steps: u64, segments: u64) -> Vec<String> {
    let k = 2;
    let rate = 0.5f32;
    let spec = {
        let mut s = JobSpec::quick(Preset::Mlp, steps);
        s.data_seed ^= 0xA0D1; // distinct stream from the other comparisons
        s
    };
    let mut out = Vec::new();
    let mut verdicts = Vec::new();
    let mut worker_steps = Vec::new();
    for &optimistic in &[false, true] {
        let pool = WorkerPool::new(
            (0..4)
                .map(|i| {
                    let name = format!("w{i}");
                    PooledWorker::new(&name, spawn(WorkerHost::new(&name, FaultPlan::Honest)))
                })
                .collect(),
        );
        let delegation = Delegation::start(&pool, ServiceConfig::new(k));
        let mut req = JobRequest::new(spec).with_segments(segments);
        if optimistic {
            req = req.with_audit(rate);
        } else {
            req = req.with_state_transfer();
        }
        let t0 = Instant::now();
        let outcome = delegation.submit(req).wait();
        let wall = t0.elapsed();
        assert!(outcome.accepted.is_some(), "audited job must resolve");
        verdicts.push(outcome.accepted);
        let report = delegation.finish();
        let total_steps = report.total_steps_trained() + report.total_audit_steps();
        worker_steps.push(total_steps);
        let mode = if optimistic { "optimistic" } else { "replicated" };
        println!(
            "  audit_{:<10} 1 job   {} x{segments} segments of {steps} steps  {:>10.2?}  {:>5} worker-steps  {} audits sampled, {} passed",
            mode,
            if optimistic { format!("rate={rate}") } else { format!("k={k}") },
            wall,
            total_steps,
            report.total_audit_sampled(),
            report.total_audit_passed(),
        );
        if optimistic {
            assert_eq!(
                report.total_audit_passed(),
                report.total_audit_sampled(),
                "honest fleet: every sampled audit must pass"
            );
            assert_eq!(report.total_audit_escalated(), 0, "honest fleet never escalates");
            assert_eq!(report.total_slashed(), 0, "honest fleet is never slashed");
        }
        out.push(format!(
            "{{\"name\":\"audit_{}_s{}x{}\",\"mode\":\"{}\",\"k\":{},\"audit_rate\":{},\
             \"wall_s\":{:.6},\"worker_steps\":{},\"audit_sampled\":{},\"audit_passed\":{},\
             \"audit_steps\":{}}}",
            mode,
            steps,
            segments,
            mode,
            k,
            if optimistic { rate } else { 0.0 },
            wall.as_secs_f64(),
            total_steps,
            report.total_audit_sampled(),
            report.total_audit_passed(),
            report.total_audit_steps(),
        ));
    }
    assert_eq!(verdicts[0], verdicts[1], "optimistic and replicated verdicts must agree");
    assert!(
        worker_steps[1] < worker_steps[0],
        "optimistic tier ({} worker-steps) must undercut k-replication ({})",
        worker_steps[1],
        worker_steps[0],
    );
    out
}

/// Nearest-rank percentile over an ascending-sorted slice of seconds.
fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

/// Latency-distribution mode: one delegation with span tracing enabled,
/// per-job submit→settle latency read back from the span timelines, and
/// the live stats snapshot written to `STATS_snapshot.json` alongside the
/// bench JSON.
fn run_latency_distribution(smoke: bool) -> String {
    let (workers, jobs, steps) = if smoke { (4usize, 8u64, 4u64) } else { (8, 32, 6) };
    let k = 2;
    let pool = WorkerPool::new(
        (0..workers)
            .map(|i| {
                let name = format!("w{i}");
                PooledWorker::new(&name, spawn(WorkerHost::new(&name, plan_for(i, workers / 4))))
            })
            .collect(),
    );
    let delegation = Delegation::start(&pool, ServiceConfig::new(k));
    let registry = delegation.registry().clone();
    registry.spans().enable();

    let handles: Vec<_> = job_batch(jobs, steps)
        .into_iter()
        .map(|spec| delegation.submit(JobRequest::new(spec)))
        .collect();
    for h in &handles {
        h.wait();
    }
    let report = delegation.finish();
    assert_eq!(
        report.outcomes.iter().filter(|o| o.accepted.is_some()).count(),
        jobs as usize,
        "all jobs must resolve"
    );

    let mut lat: Vec<f64> =
        registry.spans().job_latencies().iter().map(|d| d.as_secs_f64()).collect();
    assert_eq!(lat.len(), jobs as usize, "every job must trace a submit→settle pair");
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p90, p99) =
        (percentile(&lat, 50.0), percentile(&lat, 90.0), percentile(&lat, 99.0));
    println!(
        "  latency_w{workers}_k{k}    {jobs:>3} jobs  p50 {:>8.2}ms  p90 {:>8.2}ms  p99 {:>8.2}ms  ({} span events)",
        p50 * 1e3,
        p90 * 1e3,
        p99 * 1e3,
        registry.spans().events().len(),
    );

    match std::fs::write("STATS_snapshot.json", registry.snapshot().to_json()) {
        Ok(()) => println!("wrote STATS_snapshot.json"),
        Err(e) => eprintln!("could not write STATS_snapshot.json: {e}"),
    }

    format!(
        "{{\"name\":\"latency_w{}_k{}\",\"mode\":\"event\",\"jobs\":{},\"steps\":{},\
         \"p50_s\":{:.6},\"p90_s\":{:.6},\"p99_s\":{:.6},\"span_events\":{}}}",
        workers,
        k,
        jobs,
        steps,
        p50,
        p90,
        p99,
        registry.spans().events().len(),
    )
}

fn main() {
    // `--smoke` (the CI mode) runs one in-process scenario and the
    // smallest TCP fleet only, so the bench is exercised on every push
    // without CI paying for the full sweep — it can't silently rot.
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "SERVICE: delegation-service throughput (jobs/sec, bytes/job){}",
        if smoke { " [smoke]" } else { "" }
    );
    let scenarios = [
        Scenario { name: "honest_w4_k2", workers: 4, faulty: 0, k: 2, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w4_k2", workers: 4, faulty: 1, k: 2, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w4_k4", workers: 4, faulty: 2, k: 4, jobs: 8, steps: 6 },
        Scenario { name: "mixed_w8_k2", workers: 8, faulty: 2, k: 2, jobs: 16, steps: 6 },
        Scenario { name: "adversarial_w6_k3", workers: 6, faulty: 3, k: 3, jobs: 9, steps: 6 },
    ];
    let scenarios = if smoke { &scenarios[..1] } else { &scenarios[..] };
    let mut lines: Vec<String> = scenarios.iter().map(run_scenario).collect();

    println!("SERVICE: checkpoint state-transfer vs prefix re-training (sharded jobs)");
    let (steps, segments) = if smoke { (16, 4) } else { (48, 6) };
    lines.extend(run_transfer_compare(steps, segments));

    println!("SERVICE: optimistic audit tier vs k-replication (sharded jobs)");
    lines.extend(run_audit_compare(steps, segments));

    println!("SERVICE: per-job latency distribution (span timelines)");
    lines.push(run_latency_distribution(smoke));

    println!("SERVICE: write-ahead journal fsync overhead (durable vs ephemeral)");
    lines.extend(run_journal_compare(smoke));

    println!("SERVICE: fleet-size sweep (open mux connections on one event loop)");
    let fleet_sizes: &[usize] = if smoke { &[64] } else { &[64, 256, 1024] };
    for &size in fleet_sizes {
        lines.push(run_fleet_sweep(size));
    }

    println!("SERVICE: blocking vs multiplexed dispatch over TCP fleets");
    let sizes: &[usize] = if smoke { &[4] } else { &[4, 16, 64] };
    let mut blocking_w4_jps = 0.0f64;
    for &size in sizes {
        for &mux_mode in &[false, true] {
            let (json, jps, threads) = run_tcp_dispatch(size, mux_mode);
            if !mux_mode && size == 4 {
                blocking_w4_jps = jps;
            }
            if mux_mode && size == 64 {
                assert!(
                    threads <= 8,
                    "event core must drive 64 workers with ≤ 8 coordinator threads, used {threads}"
                );
                assert!(
                    jps >= blocking_w4_jps,
                    "multiplexed 64-worker dispatch ({jps:.2} jobs/s) must not be slower than \
                     blocking dispatch at pool size 4 ({blocking_w4_jps:.2} jobs/s)"
                );
            }
            lines.push(json);
        }
    }

    let json = format!("[\n  {}\n]\n", lines.join(",\n  "));
    for line in &lines {
        println!("JSON {line}");
    }
    match std::fs::write("BENCH_service.json", &json) {
        Ok(()) => println!("wrote BENCH_service.json"),
        Err(e) => eprintln!("could not write BENCH_service.json: {e}"),
    }
}
