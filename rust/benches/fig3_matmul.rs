//! FIG3 — paper Figure 3: RepOps matrix-multiplication overhead vs size,
//! plus the multicore RepOps scoreboard.
//!
//! Paper setup: torch::mm/cuDNN baseline vs RepOps CUDA kernels on T4 and
//! RTX 3090; overhead 30–70% at n ≥ 2^10, up to ~200% at small sizes.
//! Ours: free-order FMA baseline (per simulated profile) vs RepOps in both
//! contracts — separate-rounding (the portable §3.2 spec) and FMA (the
//! XLA/FFMA contract). Overhead % = repops/baseline − 1, measured at
//! threads = 1 so the comparison stays like-for-like (the free-order
//! baseline deliberately stays single-core — it simulates a reduction
//! schedule, not wall-clock).
//!
//! The threads dimension sweeps {1, 2, 4, hw} (deduped, capped at the
//! machine). Before timing each (n, threads) cell the bench asserts the
//! result is **bitwise identical** to the threads = 1 reference — the
//! §3.2 contract the parallel kernels must preserve.
//!
//! Emits `BENCH_repops.json` (every cell + per-size speedup records) so
//! the perf trajectory is machine-readable run over run.
//!
//! Run: `cargo bench --bench fig3_matmul`
//! Flags: `--smoke` (small sizes, short budgets, for quick CI smoke),
//!        `--assert-speedup` (exit non-zero unless multicore throughput
//!        ≥ single-core for every n ≥ 512 — the CI perf gate).

use std::time::Duration;

use verde::tensor::profile::HardwareProfile;
use verde::tensor::{baseline, repops, Tensor};
use verde::util::bench::{overhead_pct, time_adaptive};
use verde::util::parallel;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let assert_speedup = std::env::args().any(|a| a == "--assert-speedup");
    let hw_threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_set: Vec<usize> =
        [1usize, 2, 4, hw_threads].into_iter().filter(|&t| t <= hw_threads).collect();
    thread_set.sort_unstable();
    thread_set.dedup();

    let sizes: &[usize] =
        if smoke { &[64, 256, 512] } else { &[32, 64, 128, 256, 512, 1024] };
    let profiles = [HardwareProfile::T4_16G, HardwareProfile::RTX3090_24G];

    println!(
        "FIG3: RepOps matmul, {} hw cores, threads {:?}{}",
        hw_threads,
        thread_set,
        if smoke { " [smoke]" } else { "" }
    );
    println!(
        "{:>6} {:>8} {:>12} {:>12} {:>10}",
        "n", "threads", "rep GF/s", "repfma GF/s", "speedup"
    );

    let mut lines: Vec<String> = Vec::new();
    let mut gate_failures: Vec<String> = Vec::new();

    for &n in sizes {
        let a = Tensor::rand([n, n], 1, 1.0);
        let b = Tensor::rand([n, n], 2, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        let budget = Duration::from_millis(match (smoke, n >= 512) {
            (true, _) => 200,
            (false, true) => 1200,
            (false, false) => 400,
        });

        // the threads = 1 reference bits every other cell must reproduce
        parallel::set_threads(1);
        let ref_rep = repops::matmul(&a, &b);
        let ref_fma = repops::matmul_fma(&a, &b);

        let mut rep_t1_s = f64::NAN;
        let mut rep_best_s = f64::NAN;
        for &t in &thread_set {
            parallel::set_threads(t);
            assert!(
                repops::matmul(&a, &b).bit_eq(&ref_rep),
                "matmul bits diverge at n={n}, threads={t}"
            );
            assert!(
                repops::matmul_fma(&a, &b).bit_eq(&ref_fma),
                "matmul_fma bits diverge at n={n}, threads={t}"
            );
            let rep = time_adaptive("rep", budget, 50, || repops::matmul(&a, &b));
            let repf = time_adaptive("repfma", budget, 50, || repops::matmul_fma(&a, &b));
            if t == 1 {
                rep_t1_s = rep.median_secs();
            }
            rep_best_s = rep.median_secs(); // thread_set ascends; last = max threads
            let speedup = rep_t1_s / rep.median_secs();
            println!(
                "{:>6} {:>8} {:>12.2} {:>12.2} {:>9.2}x",
                n,
                t,
                flops / rep.median_secs() / 1e9,
                flops / repf.median_secs() / 1e9,
                speedup
            );
            lines.push(format!(
                "{{\"bench\":\"repops\",\"kind\":\"rep\",\"n\":{n},\"threads\":{t},\
                 \"rep_s\":{:.6},\"repfma_s\":{:.6},\"rep_gflops\":{:.2},\"bitwise_ok\":true}}",
                rep.median_secs(),
                repf.median_secs(),
                flops / rep.median_secs() / 1e9,
            ));
        }

        let max_t = *thread_set.last().unwrap();
        let speedup = rep_t1_s / rep_best_s;
        lines.push(format!(
            "{{\"bench\":\"repops\",\"kind\":\"speedup\",\"n\":{n},\"threads\":{max_t},\
             \"hw_threads\":{hw_threads},\"speedup\":{speedup:.3}}}"
        ));
        if n >= 512 && speedup < 1.0 {
            gate_failures
                .push(format!("n={n}: {max_t}-thread speedup {speedup:.2}x < 1.0x"));
        }
        if n >= 1024 && max_t >= 4 && speedup < 2.0 {
            println!("  note: n={n} speedup {speedup:.2}x below the 2x target on this machine");
        }

        // overhead vs the free-order baselines, like-for-like at 1 thread
        parallel::set_threads(1);
        let rep1 = time_adaptive("rep", budget, 50, || repops::matmul(&a, &b));
        let repf1 = time_adaptive("repfma", budget, 50, || repops::matmul_fma(&a, &b));
        for hw in &profiles {
            let base = time_adaptive("base", budget, 50, || baseline::matmul(&a, &b, hw));
            let o = overhead_pct(&rep1, &base);
            let of = overhead_pct(&repf1, &base);
            println!(
                "{:>6} {:>8} base[{}] {:.2} GF/s  ovh {:+.1}%  ovh-fma {:+.1}%",
                n,
                "serial",
                hw.name,
                flops / base.median_secs() / 1e9,
                o,
                of
            );
            lines.push(format!(
                "{{\"bench\":\"repops\",\"kind\":\"overhead\",\"n\":{n},\"profile\":\"{}\",\
                 \"base_s\":{:.6},\"rep_s\":{:.6},\"repfma_s\":{:.6},\
                 \"overhead_pct\":{:.2},\"overhead_fma_pct\":{:.2}}}",
                hw.name,
                base.median_secs(),
                rep1.median_secs(),
                repf1.median_secs(),
                o,
                of
            ));
        }
    }

    let json = format!("[\n  {}\n]\n", lines.join(",\n  "));
    for line in &lines {
        println!("JSON {line}");
    }
    match std::fs::write("BENCH_repops.json", &json) {
        Ok(()) => println!("wrote BENCH_repops.json"),
        Err(e) => eprintln!("could not write BENCH_repops.json: {e}"),
    }

    println!("\npaper reference: T4 steady-state ≈35%, RTX3090 ≈60–70%, small sizes up to ~200%");
    if assert_speedup {
        if gate_failures.is_empty() {
            println!("speedup gate passed: multicore >= single-core for all n >= 512");
        } else {
            eprintln!("speedup gate FAILED:");
            for f in &gate_failures {
                eprintln!("  {f}");
            }
            std::process::exit(1);
        }
    }
}
