//! FIG3 — paper Figure 3: RepOps matrix-multiplication overhead vs size.
//!
//! Paper setup: torch::mm/cuDNN baseline vs RepOps CUDA kernels on T4 and
//! RTX 3090; overhead 30–70% at n ≥ 2^10, up to ~200% at small sizes.
//! Ours: free-order FMA baseline (per simulated profile) vs RepOps in both
//! contracts — separate-rounding (the portable §3.2 spec) and FMA (the
//! XLA/FFMA contract). Overhead % = repops/baseline − 1.
//!
//! Run: `cargo bench --bench fig3_matmul`

use std::time::Duration;

use verde::tensor::profile::HardwareProfile;
use verde::tensor::{baseline, repops, Tensor};
use verde::util::bench::{overhead_pct, time_adaptive};

fn main() {
    let sizes = [32usize, 64, 128, 256, 512, 1024];
    let profiles = [HardwareProfile::T4_16G, HardwareProfile::RTX3090_24G];
    println!("FIG3: RepOps matmul overhead vs matrix size (square n x n)");
    println!(
        "{:>6} {:>14} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "n", "profile", "base GF/s", "rep GF/s", "repfma GF/s", "ovh%", "ovh-fma%"
    );
    for &n in &sizes {
        let a = Tensor::rand([n, n], 1, 1.0);
        let b = Tensor::rand([n, n], 2, 1.0);
        let flops = 2.0 * (n as f64).powi(3);
        let budget = Duration::from_millis(if n >= 512 { 1200 } else { 400 });
        let rep = time_adaptive("rep", budget, 50, || repops::matmul(&a, &b));
        let repf = time_adaptive("repfma", budget, 50, || repops::matmul_fma(&a, &b));
        for hw in &profiles {
            let base =
                time_adaptive("base", budget, 50, || baseline::matmul(&a, &b, hw));
            let o = overhead_pct(&rep, &base);
            let of = overhead_pct(&repf, &base);
            println!(
                "{:>6} {:>14} {:>12.2} {:>12.2} {:>12.2} {:>10.1} {:>10.1}",
                n,
                hw.name,
                flops / base.median_secs() / 1e9,
                flops / rep.median_secs() / 1e9,
                flops / repf.median_secs() / 1e9,
                o,
                of
            );
            println!(
                "JSON {{\"bench\":\"fig3\",\"n\":{n},\"profile\":\"{}\",\"base_s\":{:.6},\"rep_s\":{:.6},\"repfma_s\":{:.6},\"overhead_pct\":{:.2},\"overhead_fma_pct\":{:.2}}}",
                hw.name,
                base.median_secs(),
                rep.median_secs(),
                repf.median_secs(),
                o,
                of
            );
        }
    }
    println!("\npaper reference: T4 steady-state ≈35%, RTX3090 ≈60–70%, small sizes up to ~200%");
}
