//! TAB1 — paper Table 1: RepOps inference and training overheads for the
//! DistilBERT and Llama-1B stand-ins on the T4 / A100-40G profiles.
//!
//! Paper numbers (FP32, worst batch size 2–8):
//!              DistilBERT          Llama-1B
//!   T4-16G     74% inf / 258% trn  218% inf / 374% trn
//!   A100-40G   84% inf / 312% trn   58% inf /  67% trn
//!
//! Ours: the same program executed by the graph engine under Backend::Rep
//! vs Backend::Free(profile); overhead % per (model, task, profile).
//!
//! Run: `cargo bench --bench tab1_models`

use std::collections::BTreeMap;
use std::time::Duration;

use verde::graph::autodiff::Optimizer;
use verde::graph::executor::{execute, ExecOpts, State};
use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::tensor::profile::HardwareProfile;
use verde::tensor::Tensor;
use verde::train::data::DataGen;
use verde::util::bench::{overhead_pct, time_adaptive};

fn bench_model(preset: Preset, batch: usize, seq: usize) {
    let model = preset.build(batch, seq);
    let opt = Optimizer::adam(1e-3);
    let train = model.train_step(&opt);
    let state: State = model.init_state(7, &opt);
    let data = DataGen::new(preset, batch, seq, 11);
    let b: BTreeMap<String, Tensor> = data.batch(1);
    let fwd_graph = &model.builder.graph;
    let trn_graph = &train.graph;
    let budget = Duration::from_millis(900);

    let inf_rep = time_adaptive("inf rep", budget, 40, || {
        execute(fwd_graph, &state, &b, Backend::Rep, 1, &ExecOpts::default())
    });
    let trn_rep = time_adaptive("trn rep", budget, 40, || {
        execute(trn_graph, &state, &b, Backend::Rep, 1, &ExecOpts::default())
    });
    for hw in [HardwareProfile::T4_16G, HardwareProfile::A100_40G] {
        let inf_free = time_adaptive("inf free", budget, 40, || {
            execute(fwd_graph, &state, &b, Backend::Free(hw), 1, &ExecOpts::default())
        });
        let trn_free = time_adaptive("trn free", budget, 40, || {
            execute(trn_graph, &state, &b, Backend::Free(hw), 1, &ExecOpts::default())
        });
        let oi = overhead_pct(&inf_rep, &inf_free);
        let ot = overhead_pct(&trn_rep, &trn_free);
        println!(
            "  {:<12} {:<12} infer {:>8.1}%  train {:>8.1}%   (rep {:.1}/{:.1} ms, free {:.1}/{:.1} ms)",
            preset.name(),
            hw.name,
            oi,
            ot,
            inf_rep.median_secs() * 1e3,
            trn_rep.median_secs() * 1e3,
            inf_free.median_secs() * 1e3,
            trn_free.median_secs() * 1e3,
        );
        println!(
            "JSON {{\"bench\":\"tab1\",\"model\":\"{}\",\"profile\":\"{}\",\"infer_overhead_pct\":{oi:.2},\"train_overhead_pct\":{ot:.2}}}",
            preset.name(),
            hw.name
        );
    }
}

fn main() {
    println!("TAB1: RepOps model overheads (worst batch per paper = small batch)");
    // DistilBERT stand-in and Llama-1B stand-in, batch 2 (paper's worst)
    bench_model(Preset::BertSmall, 2, 32);
    bench_model(Preset::LlamaSmall, 2, 32);
    println!("\npaper reference:");
    println!("  DistilBERT: T4 74%/258%, A100-40G 84%/312%");
    println!("  Llama-1B:   T4 218%/374%, A100-40G 58%/67%");
}
