//! P1COST(a) — checkpoint hashing cost (paper §2.1's premise that hashing
//! is cheap relative to training, with the worked numbers: DistilBERT <1s,
//! Llama-1B ≈2.5s, Llama-8B ≈15s for weights+Adam state in FP32).
//!
//! We measure SHA-256 throughput on state-sized buffers, hash our actual
//! model states, and extrapolate to the paper's model sizes.
//!
//! Run: `cargo bench --bench hashing`

use std::time::Duration;

use verde::graph::autodiff::Optimizer;
use verde::hash::hash_tensor;
use verde::model::Preset;
use verde::tensor::Tensor;
use verde::train::checkpoint::{adam_state_bytes, PAPER_MODELS};
use verde::util::bench::time_adaptive;
use verde::util::metrics::human_bytes;

fn main() {
    println!("P1COST(a): checkpoint hashing");
    // raw throughput
    let buf = Tensor::rand([1 << 22], 1, 1.0); // 16 MiB
    let m = time_adaptive("sha256 16MiB", Duration::from_millis(1500), 50, || {
        hash_tensor(&buf)
    });
    let gbps = buf.byte_len() as f64 / m.median_secs() / 1e9;
    println!("  sha256 throughput: {:.3} GB/s", gbps);
    println!("JSON {{\"bench\":\"hashing\",\"throughput_gbps\":{gbps:.4}}}");

    // our model states
    for preset in [Preset::LlamaTiny, Preset::BertSmall, Preset::LlamaSmall, Preset::LlamaBase] {
        let model = preset.build(2, 16);
        let st = model.init_state(1, &Optimizer::adam(1e-3));
        let mm = time_adaptive(preset.name(), Duration::from_millis(500), 50, || {
            st.leaf_hashes()
        });
        println!(
            "  {:<14} state {:>10}  hash {:>12?}",
            preset.name(),
            human_bytes(st.byte_len() as u64),
            mm.median
        );
    }

    // extrapolation to the paper's models (weights + Adam m,v in FP32)
    println!("\n  extrapolated to the paper's models at {:.2} GB/s:", gbps);
    println!("  {:<16} {:>12} {:>12} {:>10}", "model", "state", "hash time", "paper");
    let paper_ref = ["<1 s", "~2.5 s", "~15 s"];
    for ((name, params), pref) in PAPER_MODELS.iter().zip(paper_ref) {
        let bytes = adam_state_bytes(*params);
        let secs = bytes as f64 / (gbps * 1e9);
        println!(
            "  {:<16} {:>12} {:>11.2}s {:>10}",
            name,
            human_bytes(bytes),
            secs,
            pref
        );
        println!(
            "JSON {{\"bench\":\"hashing\",\"model\":\"{name}\",\"state_bytes\":{bytes},\"hash_s\":{secs:.3}}}"
        );
    }
    println!("\npaper reference (§2.1, M3 CPU): DistilBERT <1s, Llama-1B ~2.5s, Llama-8B ~15s");
}
