//! The handle-based client API in one sitting: start a long-lived
//! [`Delegation`] over an untrusted worker pool, submit jobs with per-job
//! policy (priority, replication, checkpoint-segment sharding), cancel one
//! mid-flight, and read the per-segment verdicts out of the outcomes.
//!
//! Run: `cargo run --release --example delegate_service`

use verde::model::Preset;
use verde::service::{
    Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::train::JobSpec;

fn main() {
    // 1. An untrusted provider fleet: three honest workers and one that
    //    tampers with an optimizer update — indistinguishable on the wire
    //    until a dispute opens its computation.
    let plans = [
        ("honest-0", FaultPlan::Honest),
        ("honest-1", FaultPlan::Honest),
        ("honest-2", FaultPlan::Honest),
        ("cheater", FaultPlan::Tamper { step: Some(2), delta: 0.05 }),
    ];
    let pool = WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    );

    // 2. A persistent delegation service: jobs arrive one at a time from
    //    handles, not as one batch.
    let delegation = Delegation::start(&pool, ServiceConfig::new(2));

    // 3. A big job sharded into 4 checkpoint segments: each boundary is
    //    verified by its own k=2 tournament on its own worker subset, and
    //    the final segment's verdict is the whole job's verdict.
    let big = JobSpec::quick(Preset::Mlp, 12);
    let sharded = delegation.submit(JobRequest::new(big).with_segments(4).with_priority(1));

    // 4. A quick job, and one we abandon: cancel releases its leases back
    //    to the pool so the others finish sooner.
    let mut quick = JobSpec::quick(Preset::Mlp, 4);
    quick.data_seed ^= 0xF00D;
    let quick_handle = delegation.submit(JobRequest::new(quick));
    let mut doomed = JobSpec::quick(Preset::Mlp, 200);
    doomed.data_seed ^= 0xDEAD;
    let doomed_handle = delegation.submit(JobRequest::new(doomed));
    println!(
        "cancel doomed job {}: {}",
        doomed_handle.id(),
        if doomed_handle.cancel() { "accepted" } else { "too late" }
    );

    // 5. Await the survivors and inspect per-segment verdicts.
    let big_outcome = sharded.wait();
    println!(
        "sharded job {}: accepted {} after {} disputes ({} cheater eliminations)",
        big_outcome.job_id,
        big_outcome.accepted.expect("resolved").short(),
        big_outcome.disputes,
        big_outcome.eliminated,
    );
    for seg in &big_outcome.segments {
        println!(
            "  segment {} (steps {}..={}): checkpoint {} via {:?}, winner {}",
            seg.seg,
            seg.start + 1,
            seg.end,
            seg.accepted.expect("resolved").short(),
            seg.workers,
            seg.winner.as_deref().unwrap_or("<none>"),
        );
    }
    let quick_outcome = quick_handle.wait();
    println!(
        "quick job {}: accepted {}",
        quick_outcome.job_id,
        quick_outcome.accepted.expect("resolved").short()
    );
    let doomed_outcome = doomed_handle.wait();
    assert!(doomed_outcome.cancelled);

    // 6. Close the service and read the aggregate report.
    let report = delegation.finish();
    println!("JSON {}", report.to_json());
}
