//! Quickstart: delegate a training job to two untrusted trainers, detect
//! the disagreement, and let the referee identify the cheater — the whole
//! Verde pipeline in ~40 lines.
//!
//! Run: `cargo run --release --example quickstart`

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::train::JobSpec;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn main() {
    // 1. the client fixes the program: model, steps, seeds, optimizer
    let spec = JobSpec::quick(Preset::Mlp, 16);
    println!("job: {} for {} steps", spec.preset.name(), spec.steps);

    // 2. two compute providers run it; one of them tampers with an operator
    //    output at step 9 (a lazy/backdoored trainer looks the same on the
    //    wire: a wrong tensor behind a valid-looking commitment)
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        Fault::TamperOutput { step: 9, node: 8, delta: 4.0 },
    );
    let c1 = honest.train();
    let c2 = cheat.train();
    println!("trainer A commitment: {}", c1.short());
    println!("trainer B commitment: {}", c2.short());
    assert_ne!(c1, c2, "the tamper must surface in the commitment");

    // 3. the referee (computationally limited — it recomputes ONE operator)
    //    resolves the dispute
    let report = run_dispute(spec, honest, cheat);
    println!("verdict:        {:?}", report.verdict);
    println!("diverging step: {:?}", report.diverging_step);
    println!("diverging node: {:?}", report.diverging_node);
    println!(
        "referee work:   {} (bytes moved: {} + {})",
        report.referee.to_json(),
        report.bytes[0],
        report.bytes[1]
    );
    assert_eq!(report.verdict.convicted(), Some(1));
    println!("\nOK: the dishonest trainer was identified.");
}
