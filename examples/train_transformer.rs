//! END-TO-END driver: the full system on a real (small) workload.
//!
//! 1. A client defines a causal-LM training job (llama-small, synthetic
//!    Markov corpus) and delegates it to two trainers.
//! 2. Both train for a few hundred steps with multi-level checkpoint
//!    logging; the loss curve is printed and the final commitments compared
//!    (bitwise agreement ⇒ no dispute — RepOps at work).
//! 3. A third, dishonest trainer runs the same job with a mid-run tamper;
//!    the referee localizes and convicts it.
//! 4. The AOT/PJRT path (Layer 1+2 artifacts) executes the compiled
//!    train-step artifact as the high-throughput honest engine and reports
//!    its step latency next to the Rust engine's.
//!
//! Run: `cargo run --release --example train_transformer -- [--steps N]`
//! Results recorded in EXPERIMENTS.md §E2E.

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::runtime::{artifacts_present, default_dir, from_literal, to_literal, to_literal_i32, Runtime};
use verde::tensor::Tensor;
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::util::cli::Args;
use verde::util::metrics::human_bytes;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn main() {
    let args = Args::parse();
    let steps = args.get_u64("steps", 200);
    let mut spec = JobSpec::quick(Preset::LlamaSmall, steps);
    spec.batch = args.get_usize("batch", 4);
    spec.seq = args.get_usize("seq", 32);
    spec.checkpoint_n = args.get_u64("checkpoint-n", 20);

    // --- 1+2: honest delegation ------------------------------------------
    let session = Session::new(spec);
    println!(
        "job: {} ({} params, {} graph nodes) x {} steps, batch {} seq {}",
        spec.preset.name(),
        spec.preset.build(spec.batch, spec.seq).n_params(),
        session.program.graph.len(),
        steps,
        spec.batch,
        spec.seq
    );
    let t0 = std::time::Instant::now();
    let mut a = TrainerNode::honest("trainer-a", spec);
    let ca = a.train();
    let ta = t0.elapsed();
    println!(
        "trainer A done in {ta:.1?} ({:.2} steps/s), commitment {}",
        steps as f64 / ta.as_secs_f64(),
        ca.short()
    );
    println!("loss curve (every 20 steps):");
    for (i, l) in a.losses.iter().enumerate() {
        if i % 20 == 0 || i + 1 == a.losses.len() {
            println!("  step {:>4}  loss {:.4}", i + 1, l);
        }
    }
    let first = a.losses[0];
    let last = *a.losses.last().unwrap();
    assert!(last < first, "training must reduce loss: {first} -> {last}");

    let mut b = TrainerNode::honest("trainer-b", spec);
    let cb = b.train();
    assert_eq!(ca, cb, "honest RepOps trainers agree bitwise");
    println!("trainer B agrees bitwise — no dispute. storage/trainer: {}",
        human_bytes(a.counters.get("checkpoint_bytes_stored")));

    // --- 3: audit with a cheater ------------------------------------------
    let tamper_step = steps / 2 + 3;
    let upd = *session.program.param_updates.values().map(|s| &s.node).min().unwrap();
    println!("\nauditing a third trainer with a hidden tamper at step {tamper_step}...");
    let mut cheat = TrainerNode::new(
        "trainer-c",
        spec,
        Backend::Rep,
        Fault::TamperOutput { step: tamper_step, node: upd, delta: 1e-3 },
    );
    cheat.train();
    let r = run_dispute(spec, a, cheat);
    println!("verdict: {:?}", r.verdict);
    println!(
        "localized to step {:?}, node {:?}; phase-1 rounds {}; bytes {} + {}; referee {}",
        r.diverging_step,
        r.diverging_node,
        r.phase1_rounds,
        human_bytes(r.bytes[0]),
        human_bytes(r.bytes[1]),
        r.referee.to_json()
    );
    assert_eq!(r.verdict.convicted(), Some(1));
    assert_eq!(r.diverging_step, Some(tamper_step));

    // --- 4: AOT/PJRT high-throughput path ---------------------------------
    if artifacts_present() {
        println!("\nAOT/PJRT path (compiled train_step artifact):");
        let rt = Runtime::cpu(default_dir()).unwrap();
        let manifest = rt.manifest().unwrap();
        let art = rt.load("train_step.hlo.txt").unwrap();
        let (bb, ss, vv) = (
            manifest.cfg("batch") as usize,
            manifest.cfg("seq") as usize,
            manifest.cfg("vocab") as usize,
        );
        // state: params + zero moments, manifest order
        let params: Vec<Tensor> = manifest
            .params
            .iter()
            .enumerate()
            .map(|(i, (_n, s))| Tensor::rand(s.clone(), 2000 + i as u64, 0.05))
            .collect();
        let zeros: Vec<Tensor> =
            manifest.params.iter().map(|(_n, s)| Tensor::zeros(s.clone())).collect();
        let mut lits = Vec::new();
        for t in params.iter().chain(zeros.iter()).chain(zeros.iter()) {
            lits.push(to_literal(t).unwrap());
        }
        let mut tokens = Tensor::zeros([bb, ss]);
        for (i, t) in tokens.data_mut().iter_mut().enumerate() {
            *t = ((i * 7) % vv) as f32;
        }
        let mut targets = Tensor::zeros([bb * ss]);
        for (i, t) in targets.data_mut().iter_mut().enumerate() {
            *t = ((i * 11 + 1) % vv) as f32;
        }
        lits.push(to_literal_i32(&tokens).unwrap());
        lits.push(to_literal_i32(&targets).unwrap());
        lits.push(to_literal(&Tensor::scalar(1.0)).unwrap());
        let tp = std::time::Instant::now();
        let outs = art.run(&lits).unwrap();
        let dt = tp.elapsed();
        let loss = from_literal(outs.last().unwrap()).unwrap();
        println!(
            "  compiled step: {dt:?}/step, loss {:.4} (model d={} L={})",
            loss.data()[0],
            manifest.cfg("d_model"),
            manifest.cfg("n_layers")
        );
    } else {
        println!("\n(artifacts/ missing — run `make artifacts` for the PJRT section)");
    }
    println!("\nE2E OK");
}
