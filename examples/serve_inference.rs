//! Inference serving through the AOT artifact: Python never runs here —
//! the Rust binary loads `forward.hlo.txt`, compiles it once on the PJRT
//! CPU client, and serves batched requests, reporting latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example serve_inference`

use verde::runtime::{artifacts_present, default_dir, from_literal, to_literal, to_literal_i32, Runtime};
use verde::tensor::Tensor;
use verde::util::prng::SplitMix64;

fn main() {
    if !artifacts_present() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let rt = Runtime::cpu(default_dir()).unwrap();
    println!("PJRT platform: {}", rt.platform());
    let manifest = rt.manifest().unwrap();
    let t0 = std::time::Instant::now();
    let art = rt.load("forward.hlo.txt").unwrap();
    println!("compiled forward.hlo.txt in {:?}", t0.elapsed());

    let (b, s, v) = (
        manifest.cfg("batch") as usize,
        manifest.cfg("seq") as usize,
        manifest.cfg("vocab") as usize,
    );
    // deterministic "model weights"
    let params: Vec<xla::Literal> = manifest
        .params
        .iter()
        .enumerate()
        .map(|(i, (_n, shape))| {
            to_literal(&Tensor::rand(shape.clone(), 3000 + i as u64, 0.05)).unwrap()
        })
        .collect();

    // serve a stream of batched requests
    let requests = 64;
    let mut rng = SplitMix64::new(9);
    let mut lat = Vec::with_capacity(requests);
    let mut checksum = 0.0f64;
    let serve_start = std::time::Instant::now();
    for _ in 0..requests {
        let mut tokens = Tensor::zeros([b, s]);
        for t in tokens.data_mut().iter_mut() {
            *t = rng.next_bounded(v as u64) as f32;
        }
        let mut lits = params.clone();
        lits.push(to_literal_i32(&tokens).unwrap());
        let t = std::time::Instant::now();
        let outs = art.run(&lits).unwrap();
        lat.push(t.elapsed());
        let logits = from_literal(&outs[0]).unwrap();
        checksum += logits.data()[0] as f64;
    }
    let total = serve_start.elapsed();
    lat.sort();
    let p50 = lat[lat.len() / 2];
    let p99 = lat[lat.len() * 99 / 100];
    println!("served {requests} requests (batch {b} x seq {s}):");
    println!("  p50 latency  {p50:?}");
    println!("  p99 latency  {p99:?}");
    println!(
        "  throughput   {:.1} seq/s ({:.0} tok/s)",
        (requests * b) as f64 / total.as_secs_f64(),
        (requests * b * s) as f64 / total.as_secs_f64()
    );
    println!("  checksum {checksum:.4} (anti-DCE)");
}
