//! LoRA fine-tuning under delegation (the paper's Table 2 workload):
//! base weights frozen, rank-r adapters trained — and the dispute protocol
//! still works, exercising the frozen-parameter lineage path (a frozen
//! tensor's provenance is the previous step's Init node, not an update).
//!
//! Run: `cargo run --release --example lora_finetune`

use verde::graph::autodiff::Optimizer;
use verde::graph::kernels::Backend;
use verde::model::lora::llama_tiny_lora;
use verde::model::Preset;
use verde::train::JobSpec;
use verde::verde::faults::Fault;
use verde::verde::run_dispute;
use verde::verde::trainer::TrainerNode;

fn main() {
    // stand-alone LoRA model facts
    let m = llama_tiny_lora(4, 2, 8);
    let ts = m.train_step(&Optimizer::adam(1e-2));
    let total: usize = m.n_params();
    let trainable: usize = m
        .builder
        .param_shapes
        .iter()
        .filter(|(n, _)| ts.param_updates.contains_key(n))
        .map(|(_, s)| s.iter().product::<usize>())
        .sum();
    println!(
        "llama-tiny + LoRA(r=4): {total} params, {trainable} trainable ({:.1}%)",
        100.0 * trainable as f64 / total as f64
    );

    // delegated LoRA job: base weights frozen, adapters train; the dispute
    // below exercises the frozen-parameter lineage path (a frozen tensor's
    // checkpoint provenance is the previous step's Init node)
    let spec = JobSpec::quick(Preset::LlamaTinyLora, 6);
    let mut honest = TrainerNode::honest("honest", spec);
    let mut cheat = TrainerNode::new(
        "cheat",
        spec,
        Backend::Rep,
        Fault::SkipOptimizer { step: 4 },
    );
    honest.train();
    cheat.train();
    let r = run_dispute(spec, honest, cheat);
    println!("fine-tune dispute verdict: {:?}", r.verdict);
    assert_eq!(r.verdict.convicted(), Some(1));
    println!("OK");
}
