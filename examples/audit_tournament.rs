//! The staked spot-check audit tier end to end: one **optimistic** job is
//! pinned to a single staked provider that happens to cheat mid-job. The
//! per-segment checkpoint commitments are spot-checked by sampled replay;
//! the divergent segment escalates into a dispute tournament, the cheater
//! is convicted and slashed, and the job still settles with the honest
//! verdict — for (1 + audit_rate)× the work instead of k×.
//!
//! Run: `cargo run --release --example audit_tournament`

use verde::model::Preset;
use verde::service::{
    Delegation, FaultPlan, JobRequest, PooledWorker, ServiceConfig, WorkerHost, WorkerPool,
};
use verde::train::JobSpec;

fn main() {
    // 1. A fleet with a cheater FIRST in the free list, so the optimistic
    //    job pins to it. It tampers with an optimizer update at step 5 —
    //    invisible on the wire until a replay re-derives the checkpoint.
    let plans = [
        ("cheater", FaultPlan::Tamper { step: Some(5), delta: 0.05 }),
        ("honest-0", FaultPlan::Honest),
        ("honest-1", FaultPlan::Honest),
        ("honest-2", FaultPlan::Honest),
    ];
    let pool = WorkerPool::new(
        plans
            .iter()
            .map(|&(name, plan)| PooledWorker::new(name, WorkerHost::new(name, plan)))
            .collect(),
    );

    // 2. Stake every enrolled provider 1000 units; audit sampling is
    //    deterministic in (audit_seed, job_id, segment).
    let mut cfg = ServiceConfig::new(2);
    cfg.audit_seed = 42;
    cfg.worker_stake = 1000;
    let delegation = Delegation::start(&pool, cfg);

    // 3. One optimistic job, 4 checkpoint segments, audited at rate 1.0
    //    (every commitment replayed — demo determinism; production rates
    //    are 0.05..0.25 for a (1.05..1.25)× expected cost).
    let spec = JobSpec::quick(Preset::Mlp, 12);
    let handle = delegation.submit(JobRequest::new(spec).with_segments(4).with_audit(1.0));
    let outcome = handle.wait();

    println!("--- audit trail (job {}) ---", outcome.job_id);
    for seg in &outcome.segments {
        let verdict = if !seg.audit_sampled {
            "unsampled"
        } else if seg.audit_passed {
            "replay matched commitment"
        } else if seg.audit_escalated {
            "DIVERGED -> tournament"
        } else {
            "pending"
        };
        println!(
            "segment {} (steps {}..={}): {:<26} replay steps {:>2}  slashed {:>4}  winner {}",
            seg.seg,
            seg.start + 1,
            seg.end,
            verdict,
            seg.audit_steps,
            seg.slashed,
            seg.winner.as_deref().unwrap_or("<none>"),
        );
    }

    // 4. The honest verdict must stand despite the cheating committer.
    let mut referee = verde::verde::trainer::TrainerNode::honest("ref", spec);
    let honest = referee.train();
    assert_eq!(outcome.accepted, Some(honest), "honest verdict must win");
    assert!(outcome.eliminated >= 1, "the cheater must be eliminated");

    // 5. Stake movements: the cheater's locked stake was confiscated.
    let report = delegation.finish();
    println!("--- stake ledger ---");
    for s in &report.stakes {
        println!(
            "{:<10} deposited {:>5}  locked {:>5}  slashed {:>5}  available {:>5}",
            s.worker,
            s.deposited,
            s.locked,
            s.slashed,
            s.available(),
        );
    }
    let cheat = report.stakes.iter().find(|s| s.worker == "cheater").expect("enrolled");
    assert!(cheat.slashed > 0, "conviction must slash the cheater's stake");
    println!(
        "\nOK: {} audits sampled, {} passed, {} escalated; {} stake slashed; honest verdict accepted.",
        report.total_audit_sampled(),
        report.total_audit_passed(),
        report.total_audit_escalated(),
        report.total_slashed(),
    );
}
