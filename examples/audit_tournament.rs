//! Tournament audit: a client delegates one job to FOUR providers with a
//! mix of honest and dishonest behaviours (k > 2, paper §2 footnote 1).
//! The single honest trainer's output must survive the knockout.
//!
//! Run: `cargo run --release --example audit_tournament`

use verde::graph::kernels::Backend;
use verde::model::Preset;
use verde::tensor::profile::HardwareProfile;
use verde::train::session::Session;
use verde::train::JobSpec;
use verde::verde::faults::Fault;
use verde::verde::tournament::run_tournament;
use verde::verde::trainer::TrainerNode;

fn main() {
    let spec = JobSpec::quick(Preset::LlamaTiny, 8);
    let session = Session::new(spec);
    let upd = *session.program.param_updates.values().map(|s| &s.node).min().unwrap();

    let roster: Vec<(&str, Backend, Fault)> = vec![
        ("cheat-tamper", Backend::Rep, Fault::TamperOutput { step: 3, node: upd, delta: 0.05 }),
        ("honest", Backend::Rep, Fault::None),
        ("cheat-lazy", Backend::Rep, Fault::SkipSteps { after: 4 }),
        ("sloppy-hw", Backend::Free(HardwareProfile::RTX3090_24G), Fault::NonRepHardware),
    ];
    let mut trainers: Vec<TrainerNode> = roster
        .iter()
        .map(|(name, backend, fault)| {
            print!("training {name:<14} ({fault:?})... ");
            let mut t = TrainerNode::new(name, spec, *backend, *fault);
            let c = t.train();
            println!("commitment {}", c.short());
            t
        })
        .collect();

    let honest_commit = {
        let mut h = TrainerNode::honest("ref-honest", spec);
        h.train()
    };

    let r = run_tournament(spec, &mut trainers);
    println!("\n--- tournament ---");
    println!("winner: trainer #{} ({})", r.winner, roster[r.winner].0);
    println!("disputes run: {}", r.disputes);
    for (i, v) in &r.eliminated {
        println!("eliminated {} — {:?}", roster[*i].0, v);
    }
    assert_eq!(r.accepted, honest_commit, "the honest output must be accepted");
    println!("\nOK: honest output accepted; {} cheaters exposed.", r.eliminated.len());
}
